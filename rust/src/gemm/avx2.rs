//! AVX2 microkernel for the panel-interleaved u8×i8→i32 GEMM, with an
//! optional fused requantize+ReLU epilogue.
//!
//! The pairwise trick: the pack interleaves two consecutive k-rows per
//! column (see `packed` module docs), so one 32-byte load holds 16
//! columns × 2 k-rows. Both operands are widened to i16
//! (`_mm256_cvtepi8_epi16` for B, zero-extension for the u8 A pair) and
//! reduced with `_mm256_madd_epi16`, which computes the exact i32
//! `a_even·b_even + a_odd·b_odd` per column — the `maddubs` dataflow
//! without its i16 saturation, keeping SIMD output bit-identical to the
//! scalar kernel (products ≤ 255·128 fit i16 ranges comfortably inside
//! madd's i32 accumulation). When k is odd the trailing k-row is folded
//! into the accumulators with a widened `_mm256_mullo_epi32` — integer
//! adds commute, so the whole `C_temp` tile is finished **in registers**.
//!
//! Shape: MR=2 rows × NR=32 columns per register tile → 8 ymm
//! accumulators + 4 shared widened-B vectors in flight, within the 16
//! architectural ymm registers. A full panel is walked over all of k in
//! one pass, so C is touched once per (row, panel).
//!
//! # Fused epilogue ([`gemm_rows_fused`])
//!
//! After a tile's accumulators are final, the fused variant stores the
//! i32 tile to `C_temp` (the ABFT row-checksum verification still needs
//! it) **and** requantizes the same register values straight to u8 —
//! Eq 1's affine correction, `round`, clamp, and the quantized-ReLU
//! floor — without ever reloading the i32 tile from memory. Bit-exactness
//! with the scalar `quant::requantize_cols_into` core is maintained by
//! replaying its exact f32 operation sequence ([`RequantSpec::real`]'s
//! documented order, true IEEE division, and a `round`-half-away-from-
//! zero implemented via truncate + signed adjust — `_mm256_round_ps`'s
//! nearest-even mode would diverge from Rust's `f32::round` on exact
//! ties). Columns at or beyond `n_out` (the ABFT checksum column) are
//! skipped exactly as `requantize_exclude_last_col` skips them: panels
//! that touch the payload boundary, and ragged tail panels, store i32
//! and requantize through the shared scalar core instead.

#![allow(clippy::missing_safety_doc)]

use core::arch::x86_64::*;

use super::packed::{panel_rows_scalar, PackedB, NR};
use crate::quant::{requantize_cols_into, RequantEpilogue};

/// Cached runtime AVX2 check (std memoizes the cpuid probe).
#[inline]
pub(crate) fn available() -> bool {
    std::arch::is_x86_feature_detected!("avx2")
}

/// Multiply a row block: `c[rows × nt] = a[rows × k] · B` for the full
/// panels; ragged tail panels accumulate via the shared scalar kernel, so
/// `c` must be pre-zeroed by the caller (the dispatcher does).
///
/// # Safety
/// Caller must ensure the host supports AVX2 (`available()`).
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn gemm_rows(a: &[u8], packed: &PackedB, rows: usize, c: &mut [i32]) {
    let k = packed.k;
    let nt = packed.n_total();
    debug_assert_eq!(a.len(), rows * k);
    debug_assert_eq!(c.len(), rows * nt);
    let data = packed.data().as_ptr();
    let mut j0 = 0usize;
    while j0 < nt {
        let w = NR.min(nt - j0);
        if w < NR {
            panel_rows_scalar(a, packed.data(), k, nt, rows, c, j0, w);
            j0 += w;
            continue;
        }
        let panel = data.add(j0 * k);
        let mut i = 0usize;
        while i + 2 <= rows {
            let (acc0, acc1) = panel_acc_pair(a.as_ptr().add(i * k), a.as_ptr().add((i + 1) * k), panel, k);
            store_tile(&acc0, c.as_mut_ptr().add(i * nt + j0));
            store_tile(&acc1, c.as_mut_ptr().add((i + 1) * nt + j0));
            i += 2;
        }
        if i < rows {
            let acc = panel_acc_single(a.as_ptr().add(i * k), panel, k);
            store_tile(&acc, c.as_mut_ptr().add(i * nt + j0));
        }
        j0 += NR;
    }
}

/// Fused multiply + requantize row block: identical `C_temp` bytes as
/// [`gemm_rows`], plus the payload columns of `out[rows × epi.n_out]`
/// filled with the requantized (and ReLU-floored) u8 codes. `c` must be
/// pre-zeroed (ragged panels accumulate).
///
/// # Safety
/// Caller must ensure the host supports AVX2 (`available()`).
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn gemm_rows_fused(
    a: &[u8],
    packed: &PackedB,
    rows: usize,
    c: &mut [i32],
    out: &mut [u8],
    epi: &RequantEpilogue<'_>,
) {
    let k = packed.k;
    let nt = packed.n_total();
    debug_assert_eq!(a.len(), rows * k);
    debug_assert_eq!(c.len(), rows * nt);
    debug_assert_eq!(out.len(), rows * epi.n_out);
    debug_assert_eq!(epi.a_row_sums.len(), rows);
    let data = packed.data().as_ptr();
    let ec = EpiConsts::new(epi);
    let mut j0 = 0usize;
    while j0 < nt {
        let w = NR.min(nt - j0);
        if w == NR && j0 + NR <= epi.n_out {
            // Full panel entirely inside the payload: fused path.
            let panel = data.add(j0 * k);
            let bcols = epi.b_col_sums.as_ptr().add(j0);
            let mut i = 0usize;
            while i + 2 <= rows {
                let (acc0, acc1) =
                    panel_acc_pair(a.as_ptr().add(i * k), a.as_ptr().add((i + 1) * k), panel, k);
                store_tile(&acc0, c.as_mut_ptr().add(i * nt + j0));
                store_tile(&acc1, c.as_mut_ptr().add((i + 1) * nt + j0));
                epilogue_panel_row(
                    &acc0,
                    out.as_mut_ptr().add(i * epi.n_out + j0),
                    bcols,
                    *epi.a_row_sums.get_unchecked(i),
                    &ec,
                );
                epilogue_panel_row(
                    &acc1,
                    out.as_mut_ptr().add((i + 1) * epi.n_out + j0),
                    bcols,
                    *epi.a_row_sums.get_unchecked(i + 1),
                    &ec,
                );
                i += 2;
            }
            if i < rows {
                let acc = panel_acc_single(a.as_ptr().add(i * k), panel, k);
                store_tile(&acc, c.as_mut_ptr().add(i * nt + j0));
                epilogue_panel_row(
                    &acc,
                    out.as_mut_ptr().add(i * epi.n_out + j0),
                    bcols,
                    *epi.a_row_sums.get_unchecked(i),
                    &ec,
                );
            }
        } else {
            // Boundary panel (holds the checksum column) or ragged tail:
            // compute the i32 tile, then requantize its payload columns
            // through the shared scalar core — same bits, by definition.
            if w == NR {
                let panel = data.add(j0 * k);
                let mut i = 0usize;
                while i + 2 <= rows {
                    let (acc0, acc1) =
                        panel_acc_pair(a.as_ptr().add(i * k), a.as_ptr().add((i + 1) * k), panel, k);
                    store_tile(&acc0, c.as_mut_ptr().add(i * nt + j0));
                    store_tile(&acc1, c.as_mut_ptr().add((i + 1) * nt + j0));
                    i += 2;
                }
                if i < rows {
                    let acc = panel_acc_single(a.as_ptr().add(i * k), panel, k);
                    store_tile(&acc, c.as_mut_ptr().add(i * nt + j0));
                }
            } else {
                panel_rows_scalar(a, packed.data(), k, nt, rows, c, j0, w);
            }
            let end = epi.n_out.min(j0 + w);
            if j0 < end {
                for i in 0..rows {
                    requantize_cols_into(
                        &c[i * nt..(i + 1) * nt],
                        1,
                        nt,
                        j0..end,
                        &epi.a_row_sums[i..i + 1],
                        epi.b_col_sums,
                        &epi.spec,
                        epi.relu_floor,
                        &mut out[i * epi.n_out + j0..i * epi.n_out + end],
                    );
                }
            }
        }
        j0 += w;
    }
}

/// Requantize the payload columns of an already-computed `rows × nt`
/// i32 block to u8 — the epilogue half of [`gemm_rows_fused`], sourced
/// from memory instead of live registers. The acc16 and AVX-512 kernel
/// tiers route through this after filling `c`, so every tier shares the
/// single epilogue implementation (and therefore the exact bytes): full
/// 32-column payload runs replay [`epilogue_panel_row`] on reloaded
/// tiles, and the ragged payload tail goes through the shared scalar
/// core, exactly like the fused path's boundary arm.
///
/// # Safety
/// Caller must ensure the host supports AVX2 (`available()`).
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn requant_rows(
    c: &[i32],
    rows: usize,
    nt: usize,
    epi: &RequantEpilogue<'_>,
    out: &mut [u8],
) {
    debug_assert_eq!(c.len(), rows * nt);
    debug_assert_eq!(out.len(), rows * epi.n_out);
    debug_assert_eq!(epi.a_row_sums.len(), rows);
    let ec = EpiConsts::new(epi);
    let mut j0 = 0usize;
    while j0 + NR <= epi.n_out {
        let bcols = epi.b_col_sums.as_ptr().add(j0);
        for i in 0..rows {
            let crow = c.as_ptr().add(i * nt + j0);
            let acc = [
                _mm256_loadu_si256(crow as *const __m256i),
                _mm256_loadu_si256((crow as *const __m256i).add(1)),
                _mm256_loadu_si256((crow as *const __m256i).add(2)),
                _mm256_loadu_si256((crow as *const __m256i).add(3)),
            ];
            epilogue_panel_row(
                &acc,
                out.as_mut_ptr().add(i * epi.n_out + j0),
                bcols,
                *epi.a_row_sums.get_unchecked(i),
                &ec,
            );
        }
        j0 += NR;
    }
    if j0 < epi.n_out {
        for i in 0..rows {
            requantize_cols_into(
                &c[i * nt..(i + 1) * nt],
                1,
                nt,
                j0..epi.n_out,
                &epi.a_row_sums[i..i + 1],
                epi.b_col_sums,
                &epi.spec,
                epi.relu_floor,
                &mut out[i * epi.n_out + j0..(i + 1) * epi.n_out],
            );
        }
    }
}

/// Store one finished 32-column i32 tile.
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn store_tile(acc: &[__m256i; 4], crow: *mut i32) {
    for (q, v) in acc.iter().enumerate() {
        _mm256_storeu_si256((crow as *mut __m256i).add(q), *v);
    }
}

/// Widen one 32-byte interleaved pair-block into 4 × 16-lane i16 vectors
/// covering columns [0,8), [8,16), [16,24), [24,32).
///
/// Helpers that take/return `__m256i` carry the same `target_feature`
/// as their callers: without it, a non-inlined call would cross an
/// ABI-mismatched boundary (rustc's `abi_unsupported_vector_types`
/// unsoundness) and silently corrupt the vectors.
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn widen_pair_block(panel: *const i8, byte_off: usize) -> [__m256i; 4] {
    let v0 = _mm256_loadu_si256(panel.add(byte_off) as *const __m256i);
    let v1 = _mm256_loadu_si256(panel.add(byte_off + 32) as *const __m256i);
    [
        _mm256_cvtepi8_epi16(_mm256_castsi256_si128(v0)),
        _mm256_cvtepi8_epi16(_mm256_extracti128_si256(v0, 1)),
        _mm256_cvtepi8_epi16(_mm256_castsi256_si128(v1)),
        _mm256_cvtepi8_epi16(_mm256_extracti128_si256(v1, 1)),
    ]
}

/// Broadcast the (a[2pp], a[2pp+1]) u8 pair as zero-extended i16 lanes.
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn broadcast_a_pair(arow: *const u8, pp: usize) -> __m256i {
    let lo = *arow.add(2 * pp) as i32;
    let hi = *arow.add(2 * pp + 1) as i32;
    _mm256_set1_epi32(lo | (hi << 16))
}

/// Fold the odd trailing k-row (when k is odd) into the accumulators:
/// widen 8 tail bytes at a time to i32 and `mullo` by the broadcast A
/// value — exact (products ≤ 255·128), so still bit-identical to scalar.
/// Shared with the acc16 tier, whose odd tail is folded in i32 too.
#[inline]
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn fold_tail_row(acc: &mut [__m256i; 4], tail: *const i8, a_last: i32) {
    let av = _mm256_set1_epi32(a_last);
    for (q, slot) in acc.iter_mut().enumerate() {
        let b8 = _mm_loadl_epi64(tail.add(8 * q) as *const __m128i);
        let b32 = _mm256_cvtepi8_epi32(b8);
        *slot = _mm256_add_epi32(*slot, _mm256_mullo_epi32(av, b32));
    }
}

/// Accumulate one full-width panel for a row pair, odd-k tail included —
/// the returned accumulators hold the final `C_temp` tile values.
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn panel_acc_pair(
    a0: *const u8,
    a1: *const u8,
    panel: *const i8,
    k: usize,
) -> ([__m256i; 4], [__m256i; 4]) {
    let kp = k & !1;
    let mut acc0 = [_mm256_setzero_si256(); 4];
    let mut acc1 = [_mm256_setzero_si256(); 4];
    for pp in 0..kp / 2 {
        let b = widen_pair_block(panel, pp * 2 * NR);
        let va0 = broadcast_a_pair(a0, pp);
        let va1 = broadcast_a_pair(a1, pp);
        for q in 0..4 {
            acc0[q] = _mm256_add_epi32(acc0[q], _mm256_madd_epi16(va0, b[q]));
            acc1[q] = _mm256_add_epi32(acc1[q], _mm256_madd_epi16(va1, b[q]));
        }
    }
    if k % 2 == 1 {
        let tail = panel.add(kp * NR);
        fold_tail_row(&mut acc0, tail, *a0.add(k - 1) as i32);
        fold_tail_row(&mut acc1, tail, *a1.add(k - 1) as i32);
    }
    (acc0, acc1)
}

/// Single-row variant of [`panel_acc_pair`].
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn panel_acc_single(a0: *const u8, panel: *const i8, k: usize) -> [__m256i; 4] {
    let kp = k & !1;
    let mut acc = [_mm256_setzero_si256(); 4];
    for pp in 0..kp / 2 {
        let b = widen_pair_block(panel, pp * 2 * NR);
        let va = broadcast_a_pair(a0, pp);
        for q in 0..4 {
            acc[q] = _mm256_add_epi32(acc[q], _mm256_madd_epi16(va, b[q]));
        }
    }
    if k % 2 == 1 {
        fold_tail_row(&mut acc, panel.add(kp * NR), *a0.add(k - 1) as i32);
    }
    acc
}

/// Broadcast epilogue constants, hoisted out of the tile loop.
struct EpiConsts {
    /// Scalar `α_A·β_B`, kept in scalar form: the per-row term
    /// `s_arow · a_row_sum` is computed with the exact same scalar f32
    /// multiply the scalar core uses, then broadcast.
    s_arow: f32,
    s_prod: __m256,
    s_bcol: __m256,
    s_const: __m256,
    c_beta: __m256,
    c_alpha: __m256,
    half: __m256,
    one: __m256,
    abs_mask: __m256,
    sign_mask: __m256,
    lo: __m256,
    hi: __m256,
    relu: __m256i,
    perm: __m256i,
}

impl EpiConsts {
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn new(epi: &RequantEpilogue<'_>) -> Self {
        Self {
            s_arow: epi.spec.s_arow,
            s_prod: _mm256_set1_ps(epi.spec.s_prod),
            s_bcol: _mm256_set1_ps(epi.spec.s_bcol),
            s_const: _mm256_set1_ps(epi.spec.s_const),
            c_beta: _mm256_set1_ps(epi.spec.c.beta),
            c_alpha: _mm256_set1_ps(epi.spec.c.alpha),
            half: _mm256_set1_ps(0.5),
            one: _mm256_set1_ps(1.0),
            abs_mask: _mm256_castsi256_ps(_mm256_set1_epi32(0x7fff_ffff)),
            sign_mask: _mm256_castsi256_ps(_mm256_set1_epi32(i32::MIN)),
            lo: _mm256_setzero_ps(),
            hi: _mm256_set1_ps(255.0),
            relu: _mm256_set1_epi8(epi.relu_floor as i8),
            perm: _mm256_setr_epi32(0, 4, 1, 5, 2, 6, 3, 7),
        }
    }
}

/// `f32::round` (round half AWAY from zero) for 8 lanes. `_mm256_round_ps`
/// rounds half to even, which diverges from Rust's scalar `round` on exact
/// .5 ties — so truncate and add ±1 when |frac| ≥ 0.5 instead. Exact for
/// all finite inputs: for |x| < 2²⁴ the subtraction `x - trunc(x)` is
/// exact, and for larger |x| the value is already integral (frac = 0).
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn round_half_away(x: __m256, e: &EpiConsts) -> __m256 {
    let t = _mm256_round_ps(x, _MM_FROUND_TO_ZERO | _MM_FROUND_NO_EXC);
    let frac = _mm256_sub_ps(x, t);
    let absf = _mm256_and_ps(frac, e.abs_mask);
    let ge = _mm256_cmp_ps(absf, e.half, _CMP_GE_OQ);
    let sign1 = _mm256_or_ps(_mm256_and_ps(x, e.sign_mask), e.one);
    _mm256_add_ps(t, _mm256_and_ps(ge, sign1))
}

/// Requantize one row's finished 32-column accumulator tile to u8 while it
/// is still in registers: Eq 1 affine correction in the scalar core's
/// exact operation order, output-lattice quantization (true IEEE divide,
/// round-half-away, clamp), narrow to bytes, ReLU floor, one 32-byte store.
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn epilogue_panel_row(
    acc: &[__m256i; 4],
    orow: *mut u8,
    bcols: *const i32,
    a_row_sum: i32,
    e: &EpiConsts,
) {
    // t2 = s_arow·ar is row-constant; computed in scalar f32 exactly as
    // the scalar core does, then broadcast.
    let row_term = _mm256_set1_ps(e.s_arow * a_row_sum as f32);
    let mut ri = [_mm256_setzero_si256(); 4];
    for (q, slot) in ri.iter_mut().enumerate() {
        // Scalar core order: ((s_prod·c + s_arow·ar) + s_bcol·bc) + s_const.
        let cf = _mm256_cvtepi32_ps(acc[q]);
        let bc = _mm256_cvtepi32_ps(_mm256_loadu_si256((bcols as *const __m256i).add(q)));
        let mut v = _mm256_mul_ps(e.s_prod, cf);
        v = _mm256_add_ps(v, row_term);
        v = _mm256_add_ps(v, _mm256_mul_ps(e.s_bcol, bc));
        v = _mm256_add_ps(v, e.s_const);
        // Output lattice: ((x - β_C) / α_C).round().clamp(0, 255).
        let qv = _mm256_div_ps(_mm256_sub_ps(v, e.c_beta), e.c_alpha);
        let r = round_half_away(qv, e);
        let r = _mm256_min_ps(_mm256_max_ps(r, e.lo), e.hi);
        *slot = _mm256_cvtps_epi32(r);
    }
    // Narrow 4×8 i32 (all in [0,255]) to 32 bytes. packs/packus operate
    // per 128-bit lane, so a dword permute restores column order.
    let p01 = _mm256_packs_epi32(ri[0], ri[1]);
    let p23 = _mm256_packs_epi32(ri[2], ri[3]);
    let p = _mm256_packus_epi16(p01, p23);
    let p = _mm256_permutevar8x32_epi32(p, e.perm);
    let p = _mm256_max_epu8(p, e.relu);
    _mm256_storeu_si256(orow as *mut __m256i, p);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::gemm_naive;
    use crate::util::rng::Pcg32;

    #[test]
    fn avx2_matches_naive_bitwise() {
        if !available() {
            eprintln!("SKIP: host has no AVX2");
            return;
        }
        let mut rng = Pcg32::new(0xA5);
        for &(m, k, n) in &[
            (1usize, 1usize, 1usize),
            (1, 512, 512),
            (2, 2, 32),
            (3, 129, 96),  // odd k, multi-panel
            (5, 64, 33),   // full panel + 1-col tail (ABFT shape)
            (8, 255, 160),
            (16, 512, 513),
        ] {
            let mut a = vec![0u8; m * k];
            let mut b = vec![0i8; k * n];
            rng.fill_u8(&mut a);
            rng.fill_i8(&mut b);
            let packed = PackedB::pack(&b, k, n);
            let mut c = vec![0i32; m * n];
            unsafe { gemm_rows(&a, &packed, m, &mut c) };
            assert_eq!(c, gemm_naive(&a, &b, m, k, n), "shape ({m},{k},{n})");
        }
    }

    #[test]
    fn saturating_inputs_stay_exact() {
        // The maddubs-style trick must NOT saturate: all-255 × all-±127
        // is the worst case for the i16 intermediate.
        if !available() {
            eprintln!("SKIP: host has no AVX2");
            return;
        }
        let (m, k, n) = (2usize, 64usize, 64usize);
        let a = vec![255u8; m * k];
        for fill in [127i8, -128, -127] {
            let b = vec![fill; k * n];
            let packed = PackedB::pack(&b, k, n);
            let mut c = vec![0i32; m * n];
            unsafe { gemm_rows(&a, &packed, m, &mut c) };
            assert_eq!(c, gemm_naive(&a, &b, m, k, n), "fill {fill}");
        }
    }
}
