//! AVX2 microkernel for the panel-interleaved u8×i8→i32 GEMM.
//!
//! The pairwise trick: the pack interleaves two consecutive k-rows per
//! column (see `packed` module docs), so one 32-byte load holds 16
//! columns × 2 k-rows. Both operands are widened to i16
//! (`_mm256_cvtepi8_epi16` for B, zero-extension for the u8 A pair) and
//! reduced with `_mm256_madd_epi16`, which computes the exact i32
//! `a_even·b_even + a_odd·b_odd` per column — the `maddubs` dataflow
//! without its i16 saturation, keeping SIMD output bit-identical to the
//! scalar kernel (products ≤ 255·128 fit i16 ranges comfortably inside
//! madd's i32 accumulation).
//!
//! Shape: MR=2 rows × NR=32 columns per register tile → 8 ymm
//! accumulators + 4 shared widened-B vectors in flight, within the 16
//! architectural ymm registers. A full panel is walked over all of k in
//! one pass, so C is touched once per (row, panel).
//!
//! Ragged tail panels (width < 32 — e.g. the ABFT checksum column when
//! `n % 32 == 0` makes `n_total ≡ 1 (mod 32)`) fall back to the shared
//! scalar panel kernel; they are a vanishing fraction of the work.

#![allow(clippy::missing_safety_doc)]

use core::arch::x86_64::*;

use super::packed::{panel_rows_scalar, PackedB, NR};

/// Cached runtime AVX2 check (std memoizes the cpuid probe).
#[inline]
pub(crate) fn available() -> bool {
    std::arch::is_x86_feature_detected!("avx2")
}

/// Multiply a row block: `c[rows × nt] += a[rows × k] · B`. `c` must be
/// pre-zeroed by the caller (the dispatcher does).
///
/// # Safety
/// Caller must ensure the host supports AVX2 (`available()`).
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn gemm_rows(a: &[u8], packed: &PackedB, rows: usize, c: &mut [i32]) {
    let k = packed.k;
    let nt = packed.n_total();
    debug_assert_eq!(a.len(), rows * k);
    debug_assert_eq!(c.len(), rows * nt);
    let data = packed.data().as_ptr();
    let mut j0 = 0usize;
    while j0 < nt {
        let w = NR.min(nt - j0);
        if w < NR {
            panel_rows_scalar(a, packed.data(), k, nt, rows, c, j0, w);
            j0 += w;
            continue;
        }
        let panel = data.add(j0 * k);
        let mut i = 0usize;
        while i + 2 <= rows {
            row_pair_panel(
                a.as_ptr().add(i * k),
                a.as_ptr().add((i + 1) * k),
                panel,
                k,
                c.as_mut_ptr().add(i * nt + j0),
                c.as_mut_ptr().add((i + 1) * nt + j0),
            );
            i += 2;
        }
        if i < rows {
            row_single_panel(
                a.as_ptr().add(i * k),
                panel,
                k,
                c.as_mut_ptr().add(i * nt + j0),
            );
        }
        j0 += NR;
    }
}

/// Widen one 32-byte interleaved pair-block into 4 × 16-lane i16 vectors
/// covering columns [0,8), [8,16), [16,24), [24,32).
///
/// Helpers that take/return `__m256i` carry the same `target_feature`
/// as their callers: without it, a non-inlined call would cross an
/// ABI-mismatched boundary (rustc's `abi_unsupported_vector_types`
/// unsoundness) and silently corrupt the vectors.
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn widen_pair_block(panel: *const i8, byte_off: usize) -> [__m256i; 4] {
    let v0 = _mm256_loadu_si256(panel.add(byte_off) as *const __m256i);
    let v1 = _mm256_loadu_si256(panel.add(byte_off + 32) as *const __m256i);
    [
        _mm256_cvtepi8_epi16(_mm256_castsi256_si128(v0)),
        _mm256_cvtepi8_epi16(_mm256_extracti128_si256(v0, 1)),
        _mm256_cvtepi8_epi16(_mm256_castsi256_si128(v1)),
        _mm256_cvtepi8_epi16(_mm256_extracti128_si256(v1, 1)),
    ]
}

/// Broadcast the (a[2pp], a[2pp+1]) u8 pair as zero-extended i16 lanes.
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn broadcast_a_pair(arow: *const u8, pp: usize) -> __m256i {
    let lo = *arow.add(2 * pp) as i32;
    let hi = *arow.add(2 * pp + 1) as i32;
    _mm256_set1_epi32(lo | (hi << 16))
}

/// Add the odd trailing k-row (when k is odd) into a full-width panel row
/// of C — one scalar pass, negligible next to the k/2 vector iterations.
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn add_tail_row(tail: *const i8, av: i32, crow: *mut i32) {
    for cix in 0..NR {
        *crow.add(cix) += av * *tail.add(cix) as i32;
    }
}

#[target_feature(enable = "avx2")]
unsafe fn row_pair_panel(
    a0: *const u8,
    a1: *const u8,
    panel: *const i8,
    k: usize,
    c0: *mut i32,
    c1: *mut i32,
) {
    let kp = k & !1;
    let mut acc0 = [_mm256_setzero_si256(); 4];
    let mut acc1 = [_mm256_setzero_si256(); 4];
    for pp in 0..kp / 2 {
        let b = widen_pair_block(panel, pp * 2 * NR);
        let va0 = broadcast_a_pair(a0, pp);
        let va1 = broadcast_a_pair(a1, pp);
        for q in 0..4 {
            acc0[q] = _mm256_add_epi32(acc0[q], _mm256_madd_epi16(va0, b[q]));
            acc1[q] = _mm256_add_epi32(acc1[q], _mm256_madd_epi16(va1, b[q]));
        }
    }
    for q in 0..4 {
        let p0 = (c0 as *mut __m256i).add(q);
        _mm256_storeu_si256(p0, _mm256_add_epi32(_mm256_loadu_si256(p0 as *const _), acc0[q]));
        let p1 = (c1 as *mut __m256i).add(q);
        _mm256_storeu_si256(p1, _mm256_add_epi32(_mm256_loadu_si256(p1 as *const _), acc1[q]));
    }
    if k % 2 == 1 {
        let tail = panel.add(kp * NR);
        add_tail_row(tail, *a0.add(k - 1) as i32, c0);
        add_tail_row(tail, *a1.add(k - 1) as i32, c1);
    }
}

#[target_feature(enable = "avx2")]
unsafe fn row_single_panel(a0: *const u8, panel: *const i8, k: usize, c0: *mut i32) {
    let kp = k & !1;
    let mut acc = [_mm256_setzero_si256(); 4];
    for pp in 0..kp / 2 {
        let b = widen_pair_block(panel, pp * 2 * NR);
        let va = broadcast_a_pair(a0, pp);
        for q in 0..4 {
            acc[q] = _mm256_add_epi32(acc[q], _mm256_madd_epi16(va, b[q]));
        }
    }
    for q in 0..4 {
        let p = (c0 as *mut __m256i).add(q);
        _mm256_storeu_si256(p, _mm256_add_epi32(_mm256_loadu_si256(p as *const _), acc[q]));
    }
    if k % 2 == 1 {
        add_tail_row(panel.add(kp * NR), *a0.add(k - 1) as i32, c0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::gemm_naive;
    use crate::util::rng::Pcg32;

    #[test]
    fn avx2_matches_naive_bitwise() {
        if !available() {
            eprintln!("SKIP: host has no AVX2");
            return;
        }
        let mut rng = Pcg32::new(0xA5);
        for &(m, k, n) in &[
            (1usize, 1usize, 1usize),
            (1, 512, 512),
            (2, 2, 32),
            (3, 129, 96),  // odd k, multi-panel
            (5, 64, 33),   // full panel + 1-col tail (ABFT shape)
            (8, 255, 160),
            (16, 512, 513),
        ] {
            let mut a = vec![0u8; m * k];
            let mut b = vec![0i8; k * n];
            rng.fill_u8(&mut a);
            rng.fill_i8(&mut b);
            let packed = PackedB::pack(&b, k, n);
            let mut c = vec![0i32; m * n];
            unsafe { gemm_rows(&a, &packed, m, &mut c) };
            assert_eq!(c, gemm_naive(&a, &b, m, k, n), "shape ({m},{k},{n})");
        }
    }

    #[test]
    fn saturating_inputs_stay_exact() {
        // The maddubs-style trick must NOT saturate: all-255 × all-±127
        // is the worst case for the i16 intermediate.
        if !available() {
            eprintln!("SKIP: host has no AVX2");
            return;
        }
        let (m, k, n) = (2usize, 64usize, 64usize);
        let a = vec![255u8; m * k];
        for fill in [127i8, -128, -127] {
            let b = vec![fill; k * n];
            let packed = PackedB::pack(&b, k, n);
            let mut c = vec![0i32; m * n];
            unsafe { gemm_rows(&a, &packed, m, &mut c) };
            assert_eq!(c, gemm_naive(&a, &b, m, k, n), "fill {fill}");
        }
    }
}
