//! Baseline / ablation detectors the paper argues against (§II, §IV-A):
//!
//! * [`EncodeA`] — checksum *row* appended to A instead of a column on B
//!   (§IV-A1's rejected alternative; must re-encode per call).
//! * [`Blas2Abft`] — keep S_B in a separate vector and verify with a
//!   matrix-vector product (§IV-A3's rejected "straightforward"
//!   implementation ①-④).
//! * [`Full32Abft`] — 32-bit (un-modulo'd) checksum column: perfect
//!   detection, but the checksum cannot ride in the i8 panel (§IV-A2's
//!   rejected alternative).
//! * [`dmr_gemm`] — dual modular redundancy: run twice and compare (§II,
//!   the ≥100%-overhead strawman).

use crate::gemm::{gemm_exec, gemm_naive, PackedB};

/// Encode-A ABFT: append the column-sum row `S_A[j] = Σ_i A[i][j]` as row
/// m of A, multiply, and verify per *column* of C. Detects errors in A and
/// C but NOT in B (the paper's §IV-A1 coverage argument).
pub struct EncodeA {
    pub modulus: i32,
}

impl EncodeA {
    pub fn new() -> Self {
        Self { modulus: 255 }
    }

    /// Run one protected GEMM. The checksum row is re-encoded on every call
    /// (A is the transient activation operand — nothing to amortize).
    /// Returns (C payload m×n, corrupted column indices).
    pub fn exec(
        &self,
        a: &[u8],
        packed_b: &PackedB,
        m: usize,
    ) -> (Vec<i32>, Vec<usize>) {
        let k = packed_b.k;
        assert_eq!(packed_b.extra_cols, 0, "encode-A uses a plain packed B");
        let n = packed_b.n;
        // Augment A with the mod-reduced column-sum row.
        let mut a_aug = vec![0u8; (m + 1) * k];
        a_aug[..m * k].copy_from_slice(a);
        for p in 0..k {
            let mut s = 0i64;
            for i in 0..m {
                s += a[i * k + p] as i64;
            }
            a_aug[m * k + p] = (s % self.modulus as i64) as u8;
        }
        let c = gemm_exec(&a_aug, packed_b, m + 1);
        // Verify per column: Σ_i C[i][j] ≡ C[m][j] (mod modulus).
        let mut bad = Vec::new();
        for j in 0..n {
            let mut t = 0i64;
            for i in 0..m {
                t += c[i * n + j] as i64;
            }
            if (t - c[m * n + j] as i64) % self.modulus as i64 != 0 {
                bad.push(j);
            }
        }
        (c[..m * n].to_vec(), bad)
    }
}

impl Default for EncodeA {
    fn default() -> Self {
        Self::new()
    }
}

/// BLAS-2 ABFT (§IV-A3 alternative ①-④): S_B kept separate; verification
/// computes the matrix-vector product `A · S_B` (a second pass over A)
/// and the row sums of C.
pub struct Blas2Abft {
    pub s_b: Vec<i32>,
    pub modulus: i32,
    pub k: usize,
    pub n: usize,
}

impl Blas2Abft {
    pub fn new(b: &[i8], k: usize, n: usize, modulus: i32) -> Self {
        let mut s_b = vec![0i32; k];
        for p in 0..k {
            let s: i32 = b[p * n..(p + 1) * n].iter().map(|&v| v as i32).sum();
            s_b[p] = s % modulus;
        }
        Self { s_b, modulus, k, n }
    }

    /// Run GEMM (unaugmented) then the BLAS-2 verification.
    pub fn exec(&self, a: &[u8], packed_b: &PackedB, m: usize) -> (Vec<i32>, Vec<usize>) {
        assert_eq!(packed_b.extra_cols, 0);
        let c = gemm_exec(a, packed_b, m);
        let bad = self.verify(a, &c, m);
        (c, bad)
    }

    pub fn verify(&self, a: &[u8], c: &[i32], m: usize) -> Vec<usize> {
        let (k, n) = (self.k, self.n);
        let mut bad = Vec::new();
        for i in 0..m {
            // gemv row: Σ_p A[i][p] · S_B[p]
            let mut ref_sum = 0i64;
            for p in 0..k {
                ref_sum += a[i * k + p] as i64 * self.s_b[p] as i64;
            }
            let mut t = 0i64;
            for &v in &c[i * n..(i + 1) * n] {
                t += v as i64;
            }
            if (t - ref_sum) % self.modulus as i64 != 0 {
                bad.push(i);
            }
        }
        bad
    }
}

/// 32-bit exact checksum ABFT: S_B held un-modulo'd in i32; the checksum
/// "column" is computed with a separate i32 gemv (it cannot ride in the i8
/// panel). Exact equality check → detects ANY row corruption.
pub struct Full32Abft {
    pub s_b: Vec<i32>,
    pub k: usize,
    pub n: usize,
}

impl Full32Abft {
    pub fn new(b: &[i8], k: usize, n: usize) -> Self {
        let mut s_b = vec![0i32; k];
        for p in 0..k {
            s_b[p] = b[p * n..(p + 1) * n].iter().map(|&v| v as i32).sum();
        }
        Self { s_b, k, n }
    }

    pub fn exec(&self, a: &[u8], packed_b: &PackedB, m: usize) -> (Vec<i32>, Vec<usize>) {
        assert_eq!(packed_b.extra_cols, 0);
        let c = gemm_exec(a, packed_b, m);
        let bad = self.verify(a, &c, m);
        (c, bad)
    }

    pub fn verify(&self, a: &[u8], c: &[i32], m: usize) -> Vec<usize> {
        let (k, n) = (self.k, self.n);
        let mut bad = Vec::new();
        for i in 0..m {
            let mut ref_sum = 0i64;
            for p in 0..k {
                ref_sum += a[i * k + p] as i64 * self.s_b[p] as i64;
            }
            let t: i64 = c[i * n..(i + 1) * n].iter().map(|&v| v as i64).sum();
            if t != ref_sum {
                bad.push(i);
            }
        }
        bad
    }
}

/// Dual modular redundancy: compute twice, compare element-wise.
/// Detection is perfect for any transient compute error but overhead is
/// ≥100% (§II) — the strawman the paper's <20% figure is measured against.
pub fn dmr_gemm(a: &[u8], b: &[i8], m: usize, k: usize, n: usize) -> (Vec<i32>, bool) {
    let packed = PackedB::pack(b, k, n);
    let c1 = gemm_exec(a, &packed, m);
    let c2 = gemm_naive(a, b, m, k, n);
    let mismatch = c1 != c2;
    (c1, mismatch)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn rand_ab(rng: &mut Pcg32, m: usize, k: usize, n: usize) -> (Vec<u8>, Vec<i8>) {
        let mut a = vec![0u8; m * k];
        let mut b = vec![0i8; k * n];
        rng.fill_u8(&mut a);
        rng.fill_i8(&mut b);
        (a, b)
    }

    #[test]
    fn encode_a_clean_and_detects_c_error() {
        let mut rng = Pcg32::new(61);
        let (m, k, n) = (8, 64, 32);
        let (a, b) = rand_ab(&mut rng, m, k, n);
        let packed = PackedB::pack(&b, k, n);
        let enc = EncodeA::new();
        let (_, bad) = enc.exec(&a, &packed, m);
        assert!(bad.is_empty());
        // Encode-A cannot see B corruption by construction: corrupt B,
        // rebuild, and observe the checksums still pass (coverage argument).
        let mut b_bad = b.clone();
        b_bad[5] = b_bad[5].wrapping_add(3);
        let packed_bad = PackedB::pack(&b_bad, k, n);
        let (_, bad2) = enc.exec(&a, &packed_bad, m);
        assert!(
            bad2.is_empty(),
            "encode-A is blind to B errors (paper §IV-A1)"
        );
    }

    #[test]
    fn blas2_equivalent_verdict_to_blas3() {
        let mut rng = Pcg32::new(62);
        let (m, k, n) = (6, 96, 48);
        let (a, b) = rand_ab(&mut rng, m, k, n);
        let packed = PackedB::pack(&b, k, n);
        let blas2 = Blas2Abft::new(&b, k, n, 127);
        let (mut c, bad) = blas2.exec(&a, &packed, m);
        assert!(bad.is_empty());
        c[2 * n + 1] ^= 1 << 17;
        assert_eq!(blas2.verify(&a, &c, m), vec![2]);
    }

    #[test]
    fn full32_detects_multiples_of_127() {
        let mut rng = Pcg32::new(63);
        let (m, k, n) = (4, 32, 16);
        let (a, b) = rand_ab(&mut rng, m, k, n);
        let packed = PackedB::pack(&b, k, n);
        let f32abft = Full32Abft::new(&b, k, n);
        let (mut c, bad) = f32abft.exec(&a, &packed, m);
        assert!(bad.is_empty());
        // Delta divisible by 127 escapes mod-127 ABFT but not full-32.
        c[0] += 127 * 9;
        assert_eq!(f32abft.verify(&a, &c, m), vec![0]);
    }

    #[test]
    fn dmr_clean_run_matches() {
        let mut rng = Pcg32::new(64);
        let (m, k, n) = (3, 40, 20);
        let (a, b) = rand_ab(&mut rng, m, k, n);
        let (_, mismatch) = dmr_gemm(&a, &b, m, k, n);
        assert!(!mismatch);
    }
}
