//! Background memory scrubber for embedding tables.
//!
//! The paper's coverage argument (§IV-A1) is that the long-lived operand
//! (weights / embedding tables) is the one exposed to memory errors.
//! Reactive ABFT only notices a corrupted row when a request *touches*
//! it; with zipfian traffic, cold rows can stay silently corrupted for
//! hours. The scrubber closes that gap: it re-walks the table in fixed-
//! size strips (budgeted per serving idle slot) and compares each row's
//! code sum against the `C_T` checksum — the same invariant, applied
//! proactively. Since PR 6 the same walk also accumulates the
//! index-weighted sum and compares it against `C_W`, so the
//! sum-preserving cancellation class (±δ at two slots) is caught too,
//! and a flagged row carries enough residual information for the store
//! to attempt the R=1 single-slot self-heal
//! ([`EbChecksum::localize_slot`]). Detected rows are reported for
//! re-fetch from the model store (here: recorded + optionally repaired
//! from a shadow checksum).

use crate::abft::EbChecksum;
use crate::embedding::QuantTable8;

/// One scrub pass outcome.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct ScrubReport {
    /// Rows checked in this slice.
    pub rows_scanned: usize,
    /// Row indices whose code sum no longer matches C_T.
    pub corrupted_rows: Vec<usize>,
    /// True when the cursor wrapped (a full table pass completed).
    pub wrapped: bool,
}

/// Incremental scrubber over one table; keeps a cursor so each call
/// checks the next strip.
#[derive(Clone, Debug)]
pub struct Scrubber {
    cursor: usize,
    /// Rows per `scrub_step` call.
    pub stride: usize,
    /// Lifetime counters.
    pub total_scanned: u64,
    pub total_corrupted: u64,
    pub passes: u64,
}

impl Scrubber {
    pub fn new(stride: usize) -> Self {
        assert!(stride > 0);
        Self {
            cursor: 0,
            stride,
            total_scanned: 0,
            total_corrupted: 0,
            passes: 0,
        }
    }

    /// Scrub the next strip of `table` against `checksum`.
    pub fn scrub_step(&mut self, table: &QuantTable8, checksum: &EbChecksum) -> ScrubReport {
        self.scrub_step_rows(table, checksum, self.stride)
    }

    /// Scrub up to `rows` rows from the cursor (exact-budget pacing: the
    /// strip is clipped at the table end, `rows_scanned` reports what was
    /// actually covered, and the cursor carries across calls — the
    /// `scrub_budget` contract). `scrub_step` is this with `rows ==
    /// stride`.
    pub fn scrub_step_rows(
        &mut self,
        table: &QuantTable8,
        checksum: &EbChecksum,
        rows: usize,
    ) -> ScrubReport {
        assert_eq!(checksum.c_t.len(), table.rows);
        assert_eq!(checksum.c_w.len(), table.rows);
        let mut report = ScrubReport::default();
        let end = (self.cursor + rows).min(table.rows);
        for row in self.cursor..end {
            // One fused walk accumulates both sums — the dual check adds
            // no extra pass over the row bytes.
            let (mut s, mut w) = (0i32, 0i32);
            for (j, &q) in table.row(row).iter().enumerate() {
                s += q as i32;
                w += (j as i32 + 1) * q as i32;
            }
            if s != checksum.c_t[row] || w != checksum.c_w[row] {
                report.corrupted_rows.push(row);
            }
        }
        report.rows_scanned = end - self.cursor;
        self.total_scanned += report.rows_scanned as u64;
        self.total_corrupted += report.corrupted_rows.len() as u64;
        self.cursor = if end >= table.rows {
            report.wrapped = true;
            self.passes += 1;
            0
        } else {
            end
        };
        report
    }

    /// Scrub the whole table in one call (offline verification).
    pub fn full_pass(table: &QuantTable8, checksum: &EbChecksum) -> Vec<usize> {
        let mut s = Scrubber::new(table.rows.max(1));
        s.scrub_step(table, checksum).corrupted_rows
    }

    /// Fraction of the table covered since the last wrap.
    pub fn progress(&self, rows: usize) -> f64 {
        if rows == 0 {
            1.0
        } else {
            self.cursor as f64 / rows as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn setup(rows: usize, d: usize) -> (QuantTable8, EbChecksum) {
        let mut rng = Pcg32::new(0x5C12);
        let table = QuantTable8::random(rows, d, &mut rng);
        let cs = EbChecksum::build_8(&table);
        (table, cs)
    }

    #[test]
    fn clean_table_scrubs_clean() {
        let (table, cs) = setup(1000, 32);
        assert!(Scrubber::full_pass(&table, &cs).is_empty());
    }

    #[test]
    fn finds_every_corrupted_row() {
        let (mut table, cs) = setup(2000, 16);
        let victims = [3usize, 999, 1999];
        for &v in &victims {
            table.data[v * 16 + 5] ^= 0x40;
        }
        assert_eq!(Scrubber::full_pass(&table, &cs), victims.to_vec());
    }

    #[test]
    fn incremental_covers_whole_table() {
        let (mut table, cs) = setup(1050, 8); // not a multiple of stride
        table.data[1049 * 8] ^= 0x01; // last row, low bit — still a sum change
        let mut s = Scrubber::new(100);
        let mut found = Vec::new();
        let mut steps = 0;
        loop {
            let r = s.scrub_step(&table, &cs);
            found.extend(r.corrupted_rows);
            steps += 1;
            if r.wrapped {
                break;
            }
        }
        assert_eq!(steps, 11); // ceil(1050/100)
        assert_eq!(found, vec![1049]);
        assert_eq!(s.total_scanned, 1050);
        assert_eq!(s.passes, 1);
        assert_eq!(s.progress(1050), 0.0); // wrapped back to start
    }

    #[test]
    fn cursor_resumes_between_steps() {
        let (table, cs) = setup(500, 8);
        let mut s = Scrubber::new(200);
        assert_eq!(s.scrub_step(&table, &cs).rows_scanned, 200);
        assert!((s.progress(500) - 0.4).abs() < 1e-9);
        assert_eq!(s.scrub_step(&table, &cs).rows_scanned, 200);
        let last = s.scrub_step(&table, &cs);
        assert_eq!(last.rows_scanned, 100);
        assert!(last.wrapped);
    }

    #[test]
    fn budgeted_rows_override_the_stride_and_carry_the_cursor() {
        let (mut table, cs) = setup(100, 8);
        table.data[99 * 8] ^= 0x10;
        let mut s = Scrubber::new(10);
        // A budget call larger than the stride scans exactly that many.
        assert_eq!(s.scrub_step_rows(&table, &cs, 60).rows_scanned, 60);
        assert!((s.progress(100) - 0.6).abs() < 1e-9);
        // Clipped at the table end; the wrap is reported.
        let r = s.scrub_step_rows(&table, &cs, 60);
        assert_eq!(r.rows_scanned, 40);
        assert!(r.wrapped);
        assert_eq!(r.corrupted_rows, vec![99]);
        // Zero-row budget is a no-op that holds the cursor.
        assert_eq!(s.scrub_step_rows(&table, &cs, 0).rows_scanned, 0);
        assert_eq!(s.progress(100), 0.0);
        // And the plain scrub_step still follows the stride.
        assert_eq!(s.scrub_step(&table, &cs).rows_scanned, 10);
    }

    #[test]
    fn sum_preserving_two_slot_corruption_is_caught() {
        // +δ/−δ at two slots leaves the plain code sum intact; only the
        // index-weighted C_W comparison notices. Pin the victim slots so
        // the crafted deltas stay in byte range.
        let (mut table, _) = setup(300, 32);
        let r = 42;
        table.data[r * 32 + 2] = 100;
        table.data[r * 32 + 20] = 100;
        let cs = EbChecksum::build_8(&table);
        table.data[r * 32 + 2] += 9;
        table.data[r * 32 + 20] -= 9;
        assert_eq!(table.code_row_sum(r), cs.c_t[r], "plain sum is blind");
        assert_eq!(Scrubber::full_pass(&table, &cs), vec![r]);
    }

    #[test]
    fn even_bit_pairs_that_cancel_modulo_are_caught() {
        // The scrubber compares EXACT sums (not mod 127), so even a
        // ±127-sum change is caught.
        let (mut table, cs) = setup(100, 16);
        // Craft a delta of exactly 127 across the row: +128 on one code
        // (if possible) and -1 on another.
        let r = 7;
        let base = table.data[r * 16];
        table.data[r * 16] = base.wrapping_add(127);
        let found = Scrubber::full_pass(&table, &cs);
        assert_eq!(found, vec![r]);
    }
}
