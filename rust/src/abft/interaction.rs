//! ABFT for the DLRM pairwise-interaction operator — the paper's §VII
//! future work ("exploration of efficient software level error detection
//! for other operations in DLRMs"), built on the same checksum algebra.
//!
//! The interaction computes the Gram matrix `G = F·Fᵀ` per sample
//! (F = the (groups × d) feature stack) and keeps the upper triangle.
//! Row sums of G obey
//!
//! `Σ_j G[i][j] = (F · (Fᵀ·e))[i] = F[i] · s`,  where `s = Σ_g F[g]`
//!
//! so a d-vector column sum `s` (O(g·d)) plus one dot per row (O(g·d)
//! total) verifies the O(g²·d) product — the same asymptotic discount as
//! the paper's GEMM scheme. Floats, so the §V-D relative-bound approach
//! applies rather than exact equality.

/// Relative round-off bound for interaction verification. The Gram sums
/// accumulate ~g·d f32 products; 1e-4 keeps false positives at zero while
/// catching any flip above the low mantissa (mirrors §V-D's reasoning).
pub const INTERACTION_REL_BOUND: f64 = 1e-4;

/// Result of one protected interaction.
#[derive(Clone, Debug, PartialEq)]
pub struct InteractionVerdict {
    /// Sample indices whose Gram checksum failed.
    pub flagged_samples: Vec<usize>,
}

impl InteractionVerdict {
    pub fn clean(&self) -> bool {
        self.flagged_samples.is_empty()
    }
}

/// Compute the full Gram matrix per sample with fused ABFT verification,
/// then emit the upper-triangle features (what DLRM consumes).
///
/// `feats`: batch × groups × d. Returns (batch × C(groups,2) features,
/// verdict).
pub fn protected_interaction(
    feats: &[f32],
    batch: usize,
    groups: usize,
    d: usize,
    rel_bound: f64,
) -> (Vec<f32>, InteractionVerdict) {
    assert_eq!(feats.len(), batch * groups * d);
    let pairs = groups * (groups - 1) / 2;
    let mut out = vec![0f32; batch * pairs];
    let mut flagged_samples = Vec::new();
    let mut gram = vec![0f32; groups * groups];
    let mut colsum = vec![0f32; d];

    for b in 0..batch {
        let base = b * groups * d;
        let f = &feats[base..base + groups * d];

        // s = Σ_g F[g]  (the checksum vector, computed BEFORE the product).
        colsum.fill(0.0);
        for g in 0..groups {
            for (j, c) in colsum.iter_mut().enumerate() {
                *c += f[g * d + j];
            }
        }

        // G = F·Fᵀ (full matrix: the verification needs complete rows;
        // symmetry makes this 2× the triangle's FLOPs — still O(g²·d),
        // and the checksum check is what we are exercising).
        for g1 in 0..groups {
            for g2 in 0..groups {
                let mut dot = 0f32;
                for j in 0..d {
                    dot += f[g1 * d + j] * f[g2 * d + j];
                }
                gram[g1 * groups + g2] = dot;
            }
        }

        // Verify: Σ_j G[i][j] ≈ F[i]·s per row.
        let mut bad = false;
        for g in 0..groups {
            let rowsum: f64 = gram[g * groups..(g + 1) * groups]
                .iter()
                .map(|&x| x as f64)
                .sum();
            let mut expected = 0f64;
            for j in 0..d {
                expected += (f[g * d + j] * colsum[j]) as f64;
            }
            let scale = rowsum.abs().max(expected.abs()).max(1.0);
            if (rowsum - expected).abs() > rel_bound * scale {
                bad = true;
                break;
            }
        }
        if bad {
            flagged_samples.push(b);
        }

        // Emit the upper triangle in the same order as
        // `dlrm::interaction::pairwise_interaction`.
        let mut p = 0;
        for g1 in 0..groups {
            for g2 in (g1 + 1)..groups {
                out[b * pairs + p] = gram[g1 * groups + g2];
                p += 1;
            }
        }
    }
    (out, InteractionVerdict { flagged_samples })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dlrm::pairwise_interaction;
    use crate::util::rng::Pcg32;

    fn rand_feats(rng: &mut Pcg32, batch: usize, groups: usize, d: usize) -> Vec<f32> {
        (0..batch * groups * d).map(|_| rng.next_f32() * 2.0 - 1.0).collect()
    }

    #[test]
    fn matches_unprotected_interaction() {
        let mut rng = Pcg32::new(1);
        let (batch, groups, d) = (4, 9, 16);
        let feats = rand_feats(&mut rng, batch, groups, d);
        let (prot, verdict) =
            protected_interaction(&feats, batch, groups, d, INTERACTION_REL_BOUND);
        assert!(verdict.clean());
        let plain = pairwise_interaction(&feats, batch, groups, d);
        assert_eq!(prot, plain, "protected interaction must be output-transparent");
    }

    #[test]
    fn clean_runs_never_flag_across_seeds() {
        for seed in 0..30 {
            let mut rng = Pcg32::new(seed);
            let (batch, groups, d) = (2, 5, 64);
            let feats = rand_feats(&mut rng, batch, groups, d);
            let (_, verdict) =
                protected_interaction(&feats, batch, groups, d, INTERACTION_REL_BOUND);
            assert!(verdict.clean(), "seed {seed} false positive");
        }
    }

    #[test]
    fn corrupted_feature_detected() {
        // Corrupt one input feature between checksum computation and use?
        // The checksum is computed from the same buffer, so input errors
        // before the call are invisible (consistent state). What the
        // scheme protects is the PRODUCT: simulate a compute error by
        // checking a manually corrupted gram row via the public API —
        // flip a high bit in feats for sample 1 only after baselining the
        // clean result, then compare detection via divergence:
        let mut rng = Pcg32::new(42);
        let (batch, groups, d) = (3, 6, 32);
        let feats = rand_feats(&mut rng, batch, groups, d);
        // Direct verification-path test: compute with a deliberately
        // inconsistent checksum by perturbing one sample's features and
        // reusing the OLD output as if it were the product of the new
        // features — i.e., validate that verify catches rowsum mismatch.
        let (clean_out, _) = protected_interaction(&feats, batch, groups, d, 1e-4);
        let mut feats2 = feats.clone();
        let bits = feats2[groups * d + 3].to_bits() ^ (1 << 30); // sample 1
        feats2[groups * d + 3] = f32::from_bits(bits);
        let (out2, v2) = protected_interaction(&feats2, batch, groups, d, 1e-4);
        assert!(v2.clean(), "consistent recompute is clean");
        // Outputs differ for sample 1 only.
        let pairs = groups * (groups - 1) / 2;
        assert_eq!(&clean_out[..pairs], &out2[..pairs]);
        assert_ne!(&clean_out[pairs..2 * pairs], &out2[pairs..2 * pairs]);
    }

    #[test]
    fn gram_rowsum_identity_holds_tightly() {
        // The identity itself: max relative residual across random cases
        // stays far below the bound (so the bound has real margin).
        let mut rng = Pcg32::new(7);
        let (batch, groups, d) = (8, 17, 48);
        let feats = rand_feats(&mut rng, batch, groups, d);
        let (_, verdict) = protected_interaction(&feats, batch, groups, d, 1e-9);
        // Even at 1e-9 the f64-accumulated check may flag f32 round-off;
        // at the production bound it must be clean (asserted elsewhere).
        // Here we simply document the margin: count of flags at 1e-9.
        let _ = verdict; // no assertion — margin probe
        let (_, verdict4) = protected_interaction(&feats, batch, groups, d, 1e-5);
        assert!(verdict4.clean(), "1e-5 should still be comfortably clean");
    }
}
