//! Full Huang–Abraham ABFT with single-error *correction* (the classic
//! scheme the paper starts from in §IV before specializing to
//! detection-only): encode BOTH operands — a checksum row on A and a
//! checksum column on B — so a single corrupted element of C is located
//! at the intersection of the failing row and column and corrected from
//! either checksum (paper Eq 3a/3b and the correction equations).
//!
//! The paper rejects this for DLRM serving (encoding A costs `1/m` per
//! call and m is small); it lives here as the correction-capable upgrade
//! path (paper §VII future work) and as an ablation arm.

use crate::gemm::packed::NR;
use crate::gemm::{gemm_exec, PackedB};

/// Where the correction equations can repair from.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum CorrectionOutcome {
    /// No violations: C is clean.
    Clean,
    /// One (row, col) violation pair: corrected in place.
    Corrected { row: usize, col: usize, delta: i64 },
    /// Violations don't form a single intersection: detected, not
    /// correctable (recompute instead).
    Uncorrectable {
        bad_rows: Vec<usize>,
        bad_cols: Vec<usize>,
    },
}

/// Both-sides-encoded GEMM. The full checksums are held in i64 side
/// vectors (not modulo — correction needs exact deltas).
pub struct FullAbftGemm {
    /// B packed with its exact-sum i32 column held separately.
    packed_b: PackedB,
    /// Exact row sums of B (length k), i64.
    s_b: Vec<i64>,
    pub k: usize,
    pub n: usize,
}

impl FullAbftGemm {
    pub fn new(b: &[i8], k: usize, n: usize) -> Self {
        let mut s_b = vec![0i64; k];
        for p in 0..k {
            s_b[p] = b[p * n..(p + 1) * n].iter().map(|&v| v as i64).sum();
        }
        Self {
            packed_b: PackedB::pack(b, k, n),
            s_b,
            k,
            n,
        }
    }

    /// Compute C = A·B and the two checksum sides:
    /// row side `r[i] = Σ_p A[i][p]·S_B[p]` (what row i must sum to) and
    /// column side `c[j] = Σ_i C[i][j]` vs `S_A·B[j]`.
    pub fn exec(&self, a: &[u8], m: usize) -> (Vec<i32>, FullChecksums) {
        let c = gemm_exec(a, &self.packed_b, m);
        let checks = self.checksums(a, &c, m);
        (c, checks)
    }

    /// Recompute the expected row/column sums from the encodings.
    pub fn checksums(&self, a: &[u8], c: &[i32], m: usize) -> FullChecksums {
        let (k, n) = (self.k, self.n);
        assert_eq!(a.len(), m * k);
        assert_eq!(c.len(), m * n);
        // Expected row sums via A·S_B.
        let mut row_expected = vec![0i64; m];
        for i in 0..m {
            let arow = &a[i * k..(i + 1) * k];
            let mut acc = 0i64;
            for p in 0..k {
                acc += arow[p] as i64 * self.s_b[p];
            }
            row_expected[i] = acc;
        }
        // Expected column sums via S_A·B (S_A = column sums of A, exact).
        let mut s_a = vec![0i64; k];
        for i in 0..m {
            let arow = &a[i * k..(i + 1) * k];
            for p in 0..k {
                s_a[p] += arow[p] as i64;
            }
        }
        // Sweep B panel-contiguously (mirrors the kernel's pair-block
        // walk — no row-major shadow copy, no per-element offset math).
        let mut col_expected = vec![0i64; n];
        let data = self.packed_b.data();
        let kp = k & !1;
        let mut j0 = 0usize;
        while j0 < n {
            let w = NR.min(n - j0);
            let base = j0 * k;
            let cols = &mut col_expected[j0..j0 + w];
            for pp in 0..kp / 2 {
                let (sa0, sa1) = (s_a[2 * pp], s_a[2 * pp + 1]);
                if sa0 == 0 && sa1 == 0 {
                    continue;
                }
                let blk = &data[base + pp * 2 * w..base + (pp + 1) * 2 * w];
                for (c, slot) in cols.iter_mut().enumerate() {
                    *slot += sa0 * blk[2 * c] as i64 + sa1 * blk[2 * c + 1] as i64;
                }
            }
            if k % 2 == 1 && s_a[k - 1] != 0 {
                let sa = s_a[k - 1];
                let blk = &data[base + kp * w..base + kp * w + w];
                for (slot, &bv) in cols.iter_mut().zip(blk) {
                    *slot += sa * bv as i64;
                }
            }
            j0 += w;
        }
        FullChecksums {
            row_expected,
            col_expected,
        }
    }

    /// Verify and, if exactly one element is corrupted, correct it in
    /// place (paper's correction equations).
    pub fn verify_correct(&self, a: &[u8], c: &mut [i32], m: usize) -> CorrectionOutcome {
        let n = self.n;
        let checks = self.checksums(a, c, m);
        let mut bad_rows = Vec::new();
        for i in 0..m {
            let t: i64 = c[i * n..(i + 1) * n].iter().map(|&v| v as i64).sum();
            if t != checks.row_expected[i] {
                bad_rows.push(i);
            }
        }
        let mut bad_cols = Vec::new();
        for j in 0..n {
            let mut t = 0i64;
            for i in 0..m {
                t += c[i * n + j] as i64;
            }
            if t != checks.col_expected[j] {
                bad_cols.push(j);
            }
        }
        match (bad_rows.len(), bad_cols.len()) {
            (0, 0) => CorrectionOutcome::Clean,
            (1, 1) => {
                let (row, col) = (bad_rows[0], bad_cols[0]);
                let t: i64 = c[row * n..(row + 1) * n].iter().map(|&v| v as i64).sum();
                let delta = checks.row_expected[row] - t;
                c[row * n + col] = (c[row * n + col] as i64 + delta) as i32;
                CorrectionOutcome::Corrected { row, col, delta }
            }
            _ => CorrectionOutcome::Uncorrectable { bad_rows, bad_cols },
        }
    }
}

/// Expected row/column sums for a full-encoded product.
pub struct FullChecksums {
    pub row_expected: Vec<i64>,
    pub col_expected: Vec<i64>,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn setup(m: usize, k: usize, n: usize, seed: u64) -> (Vec<u8>, FullAbftGemm) {
        let mut rng = Pcg32::new(seed);
        let mut a = vec![0u8; m * k];
        let mut b = vec![0i8; k * n];
        rng.fill_u8(&mut a);
        rng.fill_i8(&mut b);
        (a.clone(), FullAbftGemm::new(&b, k, n))
    }

    #[test]
    fn clean_run_clean_outcome() {
        let (m, k, n) = (6, 40, 24);
        let (a, full) = setup(m, k, n, 1);
        let (mut c, _) = full.exec(&a, m);
        assert_eq!(full.verify_correct(&a, &mut c, m), CorrectionOutcome::Clean);
    }

    #[test]
    fn single_error_located_and_corrected() {
        let (m, k, n) = (8, 32, 16);
        let (a, full) = setup(m, k, n, 2);
        let (mut c, _) = full.exec(&a, m);
        let clean = c.clone();
        for &(row, col, bit) in &[(3usize, 7usize, 5u32), (0, 0, 30), (7, 15, 0)] {
            c[row * n + col] ^= 1 << bit;
            match full.verify_correct(&a, &mut c, m) {
                CorrectionOutcome::Corrected { row: r, col: j, .. } => {
                    assert_eq!((r, j), (row, col), "mislocated");
                }
                other => panic!("expected correction, got {other:?}"),
            }
            assert_eq!(c, clean, "value not restored");
        }
    }

    #[test]
    fn multi_error_detected_not_corrected() {
        let (m, k, n) = (6, 24, 12);
        let (a, full) = setup(m, k, n, 3);
        let (mut c, _) = full.exec(&a, m);
        c[1 * n + 2] ^= 1 << 9;
        c[4 * n + 8] ^= 1 << 13;
        match full.verify_correct(&a, &mut c, m) {
            CorrectionOutcome::Uncorrectable { bad_rows, bad_cols } => {
                assert_eq!(bad_rows, vec![1, 4]);
                assert_eq!(bad_cols, vec![2, 8]);
            }
            other => panic!("expected uncorrectable, got {other:?}"),
        }
    }

    #[test]
    fn two_errors_same_row_uncorrectable_but_detected() {
        let (m, k, n) = (4, 16, 10);
        let (a, full) = setup(m, k, n, 4);
        let (mut c, _) = full.exec(&a, m);
        c[2 * n + 1] ^= 1 << 8;
        c[2 * n + 5] ^= 1 << 11;
        match full.verify_correct(&a, &mut c, m) {
            CorrectionOutcome::Uncorrectable { bad_rows, bad_cols } => {
                assert_eq!(bad_rows, vec![2]);
                assert_eq!(bad_cols.len(), 2);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn exact_checksums_catch_multiples_of_127() {
        // Unlike the mod-127 detector, the exact i64 checksums have no
        // blind spots.
        let (m, k, n) = (3, 16, 8);
        let (a, full) = setup(m, k, n, 5);
        let (mut c, _) = full.exec(&a, m);
        c[5] += 127 * 3;
        assert!(matches!(
            full.verify_correct(&a, &mut c, m),
            CorrectionOutcome::Corrected { .. }
        ));
    }
}
