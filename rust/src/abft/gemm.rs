//! ABFT for low-precision GEMM (paper §IV, Algorithm 1).
//!
//! Design decisions, all from the paper:
//! * **Encode only B** (§IV-A1): B is the long-lived weight operand — one
//!   encode amortizes over many GEMMs and covers the operand most exposed
//!   to memory errors. Detection is per-*row* of C (no column checksums).
//! * **Checksum kept in 8 bits via mod 127** (§IV-A2): row sums of B are
//!   reduced mod 127 so the checksum column packs into the same i8 panel
//!   as B and rides through the same u8×i8 kernel.
//! * **Stay BLAS-3** (§IV-A3): the checksum column is packed contiguously
//!   with B ([`PackedB::pack_with_extra_col`]) and `C_temp` gets one extra
//!   column; requantization excludes it.
//!
//! Verification (Eq 3b, row form): for every row i,
//! `Σ_j C_temp[i][j] ≡ C_temp[i][n]  (mod 127)`.
//! The row sum is accumulated in i64 — with n up to 3200 and entries up to
//! ~1e8, an i32 accumulator would overflow (the paper elides this detail).

use crate::gemm::{gemm_exec_into, PackedB};

/// Paper's modulus: the largest odd number in the i8 range, and prime —
/// odd catches all single-bit flips, primality maximizes coverage of the
/// data-fluctuation model (§IV-C).
pub const DEFAULT_MODULUS: i32 = 127;

/// Encode the mod-`modulus` row-sum checksum column of a k×n i8 matrix
/// (Algorithm 1 lines 2-5). Output values lie in `(-modulus, modulus)`,
/// which fits i8 for any modulus ≤ 127.
pub fn encode_checksum_col(b: &[i8], k: usize, n: usize, modulus: i32) -> Vec<i8> {
    assert_eq!(b.len(), k * n);
    assert!((1..=127).contains(&modulus), "modulus must fit i8");
    let mut col = vec![0i8; k];
    for p in 0..k {
        let mut s = 0i32;
        for &v in &b[p * n..(p + 1) * n] {
            s += v as i32;
        }
        col[p] = (s % modulus) as i8;
    }
    col
}

/// Outcome of one protected GEMM.
#[derive(Clone, Debug, PartialEq)]
pub struct Verdict {
    /// Row indices of C whose checksum failed.
    pub corrupted_rows: Vec<usize>,
}

impl Verdict {
    pub fn clean(&self) -> bool {
        self.corrupted_rows.is_empty()
    }

    pub fn err_count(&self) -> usize {
        self.corrupted_rows.len()
    }
}

/// An ABFT-protected packed GEMM operand: B packed together with its
/// checksum column, ready for repeated protected multiplications.
#[derive(Clone, Debug)]
pub struct AbftGemm {
    pub packed: PackedB,
    pub modulus: i32,
    pub k: usize,
    pub n: usize,
}

impl AbftGemm {
    /// Encode + pack (Algorithm 1 lines 1-6). Done once per weight matrix.
    pub fn new(b: &[i8], k: usize, n: usize) -> Self {
        Self::with_modulus(b, k, n, DEFAULT_MODULUS)
    }

    pub fn with_modulus(b: &[i8], k: usize, n: usize, modulus: i32) -> Self {
        let col = encode_checksum_col(b, k, n, modulus);
        Self {
            packed: PackedB::pack_with_extra_col(b, k, n, &col),
            modulus,
            k,
            n,
        }
    }

    /// Wrap an already-packed encoded operand (used by fault campaigns that
    /// corrupt the packed bytes *after* encoding).
    pub fn from_packed(packed: PackedB, modulus: i32) -> Self {
        assert_eq!(packed.extra_cols, 1, "needs a checksum column");
        let (k, n) = (packed.k, packed.n);
        Self {
            packed,
            modulus,
            k,
            n,
        }
    }

    /// Protected GEMM (Algorithm 1 lines 7-16): compute `C_temp[m×(n+1)]`
    /// and verify every row. Returns the intermediate matrix (checksum
    /// column included — requantization must exclude it) and the verdict.
    pub fn exec(&self, a: &[u8], m: usize) -> (Vec<i32>, Verdict) {
        let mut c = vec![0i32; m * (self.n + 1)];
        let verdict = self.exec_into(a, m, &mut c);
        (c, verdict)
    }

    /// Allocation-free variant for the serving hot path.
    pub fn exec_into(&self, a: &[u8], m: usize, c_temp: &mut [i32]) -> Verdict {
        gemm_exec_into(a, &self.packed, m, c_temp);
        self.verify(c_temp, m)
    }

    /// Check Eq 3b on an already-computed `C_temp[m×(n+1)]`.
    pub fn verify(&self, c_temp: &[i32], m: usize) -> Verdict {
        let nt = self.n + 1;
        assert_eq!(c_temp.len(), m * nt);
        let mut corrupted_rows = Vec::new();
        for i in 0..m {
            let row = &c_temp[i * nt..(i + 1) * nt];
            if !row_ok(row, self.n, self.modulus) {
                corrupted_rows.push(i);
            }
        }
        Verdict { corrupted_rows }
    }

    /// Sampled Eq-3b verification: check only rows `i` with
    /// `(phase + i) % every == 0` — the policy layer's `Sampled(n)` mode.
    /// The caller advances `phase` by `m` per batch (a per-site counter),
    /// so coverage rotates across the row space instead of pinning to
    /// fixed indices. `every == 1` checks every row and is **identical**
    /// to [`AbftGemm::verify`] (property-tested in `rust/tests/prop.rs`).
    pub fn verify_sampled(&self, c_temp: &[i32], m: usize, every: u32, phase: u64) -> Verdict {
        let every = every.max(1) as u64;
        let nt = self.n + 1;
        assert_eq!(c_temp.len(), m * nt);
        let mut corrupted_rows = Vec::new();
        let mut i = ((every - phase % every) % every) as usize;
        while i < m {
            if !row_ok(&c_temp[i * nt..(i + 1) * nt], self.n, self.modulus) {
                corrupted_rows.push(i);
            }
            i += every as usize;
        }
        Verdict { corrupted_rows }
    }

    /// How many rows [`AbftGemm::verify_sampled`] checks for a given
    /// batch height and phase (telemetry accounting; no verification).
    pub fn sampled_rows(m: usize, every: u32, phase: u64) -> usize {
        let every = every.max(1) as u64;
        let first = ((every - phase % every) % every) as usize;
        if first >= m {
            0
        } else {
            1 + (m - 1 - first) / every as usize
        }
    }

    /// Batch-aggregate Eq-3b: one congruence over the whole tile,
    /// `Σ_i (Σ_j C[i][j] − C[i][n]) ≡ 0 (mod modulus)` — the policy
    /// layer's `BoundOnly` mode. Strictly weaker than per-row
    /// verification: deltas on different rows can cancel mod `modulus`,
    /// and a failure cannot name the corrupted row (recovery is the
    /// engine's batch-level retry, not a row recompute). Returns `true`
    /// when the aggregate is clean.
    pub fn verify_aggregate(&self, c_temp: &[i32], m: usize) -> bool {
        self.aggregate_residual(c_temp, m) % self.modulus as i64 == 0
    }

    /// The raw tile residual `Σ_i (Σ_j C[i][j] − C[i][n])` the aggregate
    /// congruence tests — `≡ 0 (mod modulus)` on clean data, and shifted
    /// by exactly the injected delta under corruption (the difference of
    /// two residuals over the same inputs is mod-free).
    pub fn aggregate_residual(&self, c_temp: &[i32], m: usize) -> i64 {
        let nt = self.n + 1;
        assert_eq!(c_temp.len(), m * nt);
        let mut t: i64 = 0;
        for i in 0..m {
            let row = &c_temp[i * nt..(i + 1) * nt];
            for &v in &row[..self.n] {
                t += v as i64;
            }
            t -= row[self.n] as i64;
        }
        t
    }

    /// The raw Eq-3b residual of one row, `Σ_j C[row][j] − C[row][n]` —
    /// `≡ 0 (mod modulus)` on any clean row. Taken before and after a
    /// row recompute, the residual shift is exactly the transient delta
    /// the fault injected (mod-free), which is the fault-event
    /// pipeline's severity signal.
    pub fn row_residual(&self, c_temp: &[i32], m: usize, row: usize) -> i64 {
        let nt = self.n + 1;
        assert_eq!(c_temp.len(), m * nt);
        assert!(row < m);
        let r = &c_temp[row * nt..(row + 1) * nt];
        let mut t: i64 = 0;
        for &v in &r[..self.n] {
            t += v as i64;
        }
        t - r[self.n] as i64
    }

    /// Recompute the payload of a single corrupted row from A and the packed
    /// B (row-level recovery; the paper's deployment model is "recompute on
    /// detect" since double faults are vanishingly rare).
    pub fn recompute_row(&self, a: &[u8], row: usize, c_temp: &mut [i32], m: usize) {
        let nt = self.n + 1;
        assert!(row < m);
        let arow = &a[row * self.k..(row + 1) * self.k];
        let out = &mut c_temp[row * nt..(row + 1) * nt];
        // One-row GEMM through the production kernel: same panel layout,
        // same bit-exact result as the original computation.
        crate::gemm::gemm_exec_into_scalar(arow, &self.packed, 1, out);
    }

    /// Theoretical FLOP overhead of encode+verify for one GEMM of shape
    /// (m, n, k): `1/(2m) + 1/n + 1/(2k)` (§IV-A1, encoding-B row).
    pub fn theoretical_overhead(m: usize, n: usize, k: usize) -> f64 {
        1.0 / (2.0 * m as f64) + 1.0 / n as f64 + 1.0 / (2.0 * k as f64)
    }
}

/// Row check: `Σ_j row[0..n] ≡ row[n] (mod modulus)`; i64 accumulation.
#[inline]
pub fn row_ok(row: &[i32], n: usize, modulus: i32) -> bool {
    let mut t: i64 = 0;
    for &v in &row[..n] {
        t += v as i64;
    }
    (t - row[n] as i64) % modulus as i64 == 0
}

/// §IV-A1 overhead if encoding A instead: `1/(2n) + 1/m + 1/(2k)`.
pub fn theoretical_overhead_encode_a(m: usize, n: usize, k: usize) -> f64 {
    1.0 / (2.0 * n as f64) + 1.0 / m as f64 + 1.0 / (2.0 * k as f64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn rand_ab(rng: &mut Pcg32, m: usize, k: usize, n: usize) -> (Vec<u8>, Vec<i8>) {
        let mut a = vec![0u8; m * k];
        let mut b = vec![0i8; k * n];
        rng.fill_u8(&mut a);
        rng.fill_i8(&mut b);
        (a, b)
    }

    #[test]
    fn clean_run_verifies_clean() {
        let mut rng = Pcg32::new(1);
        for &(m, k, n) in &[(1usize, 3200usize, 800usize), (4, 64, 64), (150, 256, 32)] {
            let (a, b) = rand_ab(&mut rng, m, k, n);
            let abft = AbftGemm::new(&b, k, n);
            let (_, verdict) = abft.exec(&a, m);
            assert!(verdict.clean(), "shape ({m},{k},{n})");
        }
    }

    #[test]
    fn payload_matches_unprotected_gemm() {
        let mut rng = Pcg32::new(2);
        let (m, k, n) = (5, 128, 40);
        let (a, b) = rand_ab(&mut rng, m, k, n);
        let abft = AbftGemm::new(&b, k, n);
        let (c, _) = abft.exec(&a, m);
        let plain = crate::gemm::gemm_naive(&a, &b, m, k, n);
        for i in 0..m {
            assert_eq!(&c[i * (n + 1)..i * (n + 1) + n], &plain[i * n..(i + 1) * n]);
        }
    }

    #[test]
    fn detects_corruption_in_c() {
        let mut rng = Pcg32::new(3);
        let (m, k, n) = (8, 100, 50);
        let (a, b) = rand_ab(&mut rng, m, k, n);
        let abft = AbftGemm::new(&b, k, n);
        let (mut c, _) = abft.exec(&a, m);
        // Flip a high bit in row 5.
        c[5 * (n + 1) + 7] ^= 1 << 20;
        let verdict = abft.verify(&c, m);
        assert_eq!(verdict.corrupted_rows, vec![5]);
    }

    #[test]
    fn multiple_corrupted_rows_all_reported() {
        let mut rng = Pcg32::new(4);
        let (m, k, n) = (10, 64, 30);
        let (a, b) = rand_ab(&mut rng, m, k, n);
        let abft = AbftGemm::new(&b, k, n);
        let (mut c, _) = abft.exec(&a, m);
        for &r in &[1usize, 4, 9] {
            c[r * (n + 1)] ^= 1 << 10;
        }
        let verdict = abft.verify(&c, m);
        assert_eq!(verdict.corrupted_rows, vec![1, 4, 9]);
    }

    #[test]
    fn multiple_of_modulus_escapes_as_analyzed() {
        // An injected delta divisible by 127 is undetectable — the paper's
        // §IV-C false-negative condition, reproduced exactly.
        let mut rng = Pcg32::new(5);
        let (m, k, n) = (2, 16, 8);
        let (a, b) = rand_ab(&mut rng, m, k, n);
        let abft = AbftGemm::new(&b, k, n);
        let (mut c, _) = abft.exec(&a, m);
        c[3] += 127 * 5;
        assert!(abft.verify(&c, m).clean());
        c[3] += 1;
        assert!(!abft.verify(&c, m).clean());
    }

    #[test]
    fn recompute_row_repairs() {
        let mut rng = Pcg32::new(6);
        let (m, k, n) = (6, 80, 24);
        let (a, b) = rand_ab(&mut rng, m, k, n);
        let abft = AbftGemm::new(&b, k, n);
        let (mut c, _) = abft.exec(&a, m);
        let clean = c.clone();
        c[2 * (n + 1) + 3] ^= 1 << 13;
        assert_eq!(abft.verify(&c, m).corrupted_rows, vec![2]);
        abft.recompute_row(&a, 2, &mut c, m);
        assert!(abft.verify(&c, m).clean());
        assert_eq!(c, clean);
    }

    #[test]
    fn i64_rowsum_no_overflow_on_large_n() {
        // n*max_entry exceeds i32: entries near 2^27 with n=3200 would wrap
        // an i32 accumulator. Construct a saturated case.
        let (m, k, n) = (1usize, 3200usize, 3200usize);
        let a = vec![255u8; m * k];
        let b = vec![127i8; k * n];
        let abft = AbftGemm::new(&b, k, n);
        let (_, verdict) = abft.exec(&a, m);
        assert!(verdict.clean(), "saturated case must not false-positive");
    }

    #[test]
    fn checksum_col_values_fit_i8() {
        let mut rng = Pcg32::new(7);
        let (k, n) = (500, 333);
        let mut b = vec![0i8; k * n];
        rng.fill_i8(&mut b);
        let col = encode_checksum_col(&b, k, n, 127);
        for &v in &col {
            assert!((-127..=127).contains(&(v as i32)));
        }
    }

    #[test]
    fn theoretical_overhead_prefers_b_for_dlrm_shapes() {
        // DLRM: m small, n/k large → encoding B cheaper (§IV-A1).
        for &(m, n, k) in &[(1usize, 800usize, 3200usize), (100, 512, 512)] {
            assert!(
                AbftGemm::theoretical_overhead(m, n, k)
                    < theoretical_overhead_encode_a(m, n, k)
                    || m >= n
            );
        }
    }

    #[test]
    fn sampled_verify_checks_exactly_its_stripe() {
        let mut rng = Pcg32::new(8);
        let (m, k, n) = (12, 48, 20);
        let (a, b) = rand_ab(&mut rng, m, k, n);
        let abft = AbftGemm::new(&b, k, n);
        let (mut c, _) = abft.exec(&a, m);
        // Corrupt every row: a sampled pass flags exactly its stripe.
        for r in 0..m {
            c[r * (n + 1)] ^= 1 << 9;
        }
        for every in [1u32, 2, 3, 4] {
            for phase in [0u64, 1, 5, 100] {
                let v = abft.verify_sampled(&c, m, every, phase);
                let expect: Vec<usize> =
                    (0..m).filter(|i| (phase + *i as u64) % every as u64 == 0).collect();
                assert_eq!(v.corrupted_rows, expect, "every={every} phase={phase}");
                assert_eq!(
                    AbftGemm::sampled_rows(m, every, phase),
                    expect.len(),
                    "count formula every={every} phase={phase}"
                );
            }
        }
    }

    #[test]
    fn aggregate_verify_catches_single_fault_and_admits_cancellation() {
        let mut rng = Pcg32::new(9);
        let (m, k, n) = (6, 32, 16);
        let (a, b) = rand_ab(&mut rng, m, k, n);
        let abft = AbftGemm::new(&b, k, n);
        let (mut c, _) = abft.exec(&a, m);
        assert!(abft.verify_aggregate(&c, m), "clean tile must pass");
        c[3] += 5; // single fault → aggregate residue 5
        assert!(!abft.verify_aggregate(&c, m));
        // Opposing delta on another row cancels — the documented
        // weakness that makes BoundOnly the bottom of the checked lattice.
        c[2 * (n + 1)] -= 5;
        assert!(abft.verify_aggregate(&c, m));
        assert!(!abft.verify(&c, m).clean(), "per-row verify still catches it");
    }

    #[test]
    fn residuals_track_injected_deltas() {
        let mut rng = Pcg32::new(10);
        let (m, k, n) = (4, 32, 16);
        let (a, b) = rand_ab(&mut rng, m, k, n);
        let abft = AbftGemm::new(&b, k, n);
        let (mut c, _) = abft.exec(&a, m);
        let base = abft.row_residual(&c, m, 2);
        assert_eq!(base % 127, 0, "clean row residual is ≡ 0 (mod 127)");
        let base_agg = abft.aggregate_residual(&c, m);
        assert_eq!(base_agg % 127, 0, "clean aggregate residual is ≡ 0 (mod 127)");
        c[2 * (n + 1)] += 5000;
        assert_eq!(abft.row_residual(&c, m, 2) - base, 5000);
        assert_eq!(
            abft.aggregate_residual(&c, m) - base_agg,
            5000,
            "aggregate residual carries the injected delta mod-free"
        );
    }

    #[test]
    fn requant_not_linear() {
        // §IV-B / E8: requantization is NOT linear, so checksums cannot be
        // carried through it: Q(a)+Q(b) != Q(a+b) in general.
        let qp = crate::quant::QParams::fit_u8(0.0, 100.0);
        let q = |x: f32| qp.quantize_u8(x) as i32;
        let mut violations = 0;
        for a in [3.3f32, 10.7, 55.1] {
            for b in [1.2f32, 9.9, 40.4] {
                if q(a) + q(b) != q(a + b) {
                    violations += 1;
                }
            }
        }
        assert!(violations > 0, "requantization unexpectedly linear");
    }
}
