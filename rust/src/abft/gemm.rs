//! ABFT for low-precision GEMM (paper §IV, Algorithm 1).
//!
//! Design decisions, all from the paper:
//! * **Encode only B** (§IV-A1): B is the long-lived weight operand — one
//!   encode amortizes over many GEMMs and covers the operand most exposed
//!   to memory errors. Detection is per-*row* of C (no column checksums).
//! * **Checksum kept in 8 bits via mod 127** (§IV-A2): row sums of B are
//!   reduced mod 127 so the checksum column packs into the same i8 panel
//!   as B and rides through the same u8×i8 kernel.
//! * **Stay BLAS-3** (§IV-A3): the checksum column is packed contiguously
//!   with B ([`PackedB::pack_with_extra_col`]) and `C_temp` gets one extra
//!   column; requantization excludes it.
//!
//! Verification (Eq 3b, row form): for every row i,
//! `Σ_j C_temp[i][j] ≡ C_temp[i][n]  (mod 127)`.
//! The row sum is accumulated in i64 — with n up to 3200 and entries up to
//! ~1e8, an i32 accumulator would overflow (the paper elides this detail).
//!
//! # Localization + in-place correction (PR 6)
//!
//! Beyond the Eq-3b column, the pack carries **column-group partial
//! checksums**: `G = ⌈n/32⌉` extra columns, one per [`GROUP_WIDTH`]-wide
//! payload column group, built by the same mod-127 row-sum construction
//! restricted to the group. The encoded layout is
//!
//! ```text
//! cols [0, n)              payload
//! col  [n]                 Eq-3b full row-sum checksum
//! cols [n+1, n+1+G)        group partial checksums (group g = payload
//!                          columns [g·32, min((g+1)·32, n)))
//! ```
//!
//! All extra columns ride the panel-interleaved pack and the same kernel
//! call; the requantize epilogue skips everything past `n_out = n` exactly
//! as it always skipped the single checksum column. On an Eq-3b-flagged
//! row, the intersection of the row residual with the (single) non-zero
//! group residual *names* the faulty column group; [`AbftGemm::correct_row`]
//! then re-derives only that group's ≤32 candidate entries (k MACs each —
//! `GROUP_WIDTH/n` of a full row recompute), fixes the one mismatching i32
//! accumulator entry in place, and re-checks Eq 3b. Anything other than
//! exactly-one-group/exactly-one-entry (multi-fault, operand corruption
//! where re-derivation reproduces the corrupt value) is declined and falls
//! down the recovery ladder.

use crate::gemm::packed::NR;
use crate::gemm::{gemm_exec_into, PackedB};

/// Payload columns covered by one group partial checksum — the microkernel
/// panel width, so a group residual names exactly one register tile.
pub const GROUP_WIDTH: usize = NR;

/// Paper's modulus: the largest odd number in the i8 range, and prime —
/// odd catches all single-bit flips, primality maximizes coverage of the
/// data-fluctuation model (§IV-C).
pub const DEFAULT_MODULUS: i32 = 127;

/// Encode the mod-`modulus` row-sum checksum column of a k×n i8 matrix
/// (Algorithm 1 lines 2-5). Output values lie in `(-modulus, modulus)`,
/// which fits i8 for any modulus ≤ 127.
pub fn encode_checksum_col(b: &[i8], k: usize, n: usize, modulus: i32) -> Vec<i8> {
    assert_eq!(b.len(), k * n);
    assert!((1..=127).contains(&modulus), "modulus must fit i8");
    let mut col = vec![0i8; k];
    for p in 0..k {
        let mut s = 0i32;
        for &v in &b[p * n..(p + 1) * n] {
            s += v as i32;
        }
        col[p] = (s % modulus) as i8;
    }
    col
}

/// Number of column-group partial checksums for a payload width `n`.
pub const fn group_count(n: usize) -> usize {
    n.div_ceil(GROUP_WIDTH)
}

/// Encode the `G = ⌈n/32⌉` column-group partial checksum columns of a
/// k×n i8 matrix: column `g` holds `(Σ_{j ∈ group g} B[p][j]) mod modulus`
/// per row `p` — the same Algorithm-1 construction as
/// [`encode_checksum_col`], restricted to one [`GROUP_WIDTH`]-wide group.
pub fn encode_group_checksum_cols(b: &[i8], k: usize, n: usize, modulus: i32) -> Vec<Vec<i8>> {
    assert_eq!(b.len(), k * n);
    assert!((1..=127).contains(&modulus), "modulus must fit i8");
    let groups = group_count(n);
    let mut cols = vec![vec![0i8; k]; groups];
    for p in 0..k {
        for (g, col) in cols.iter_mut().enumerate() {
            let j0 = g * GROUP_WIDTH;
            let j1 = n.min(j0 + GROUP_WIDTH);
            let mut s = 0i32;
            for &v in &b[p * n + j0..p * n + j1] {
                s += v as i32;
            }
            col[p] = (s % modulus) as i8;
        }
    }
    cols
}

/// Outcome of one attempted algebraic in-place row correction
/// ([`AbftGemm::correct_row`]) — the `CorrectInPlace` ladder rung's
/// mechanism. Distinct from `abft::full::CorrectionOutcome`, which is the
/// classic both-sides Huang–Abraham ablation; this one works on the
/// production row-checksum layout.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RowCorrection {
    /// Exactly one accumulator entry was wrong: it has been rewritten in
    /// place and the row re-verifies clean under Eq 3b. `col` is the
    /// logical C column fixed (may be `n` — the checksum entry itself);
    /// `delta` is the corruption removed (`corrupt − correct`).
    Corrected { col: usize, delta: i64 },
    /// Correction declined; the caller must fall down the recovery ladder.
    Declined(CorrectionDecline),
}

impl RowCorrection {
    pub fn corrected(&self) -> bool {
        matches!(self, RowCorrection::Corrected { .. })
    }
}

/// Why [`AbftGemm::correct_row`] declined (each is a distinct multi-fault
/// or operand-fault signature; campaigns assert on them).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CorrectionDecline {
    /// The pack carries no group checksum columns (legacy layout).
    NoGroups,
    /// More than one group residual is non-zero: ≥2 corrupted groups.
    MultiGroup,
    /// Re-deriving the candidate columns reproduced the stored values
    /// exactly — the fault is in the packed operand (re-derivation reads
    /// the same corrupt bytes), so only a true recompute/failover helps.
    NoMismatch,
    /// More than one candidate entry mismatched: multi-fault inside one
    /// group.
    MultiMismatch,
    /// The row still fails Eq 3b after the single-entry fix (faults
    /// beyond the single-corruption model).
    ReverifyFailed,
}

/// Outcome of one protected GEMM.
#[derive(Clone, Debug, PartialEq)]
pub struct Verdict {
    /// Row indices of C whose checksum failed.
    pub corrupted_rows: Vec<usize>,
}

impl Verdict {
    pub fn clean(&self) -> bool {
        self.corrupted_rows.is_empty()
    }

    pub fn err_count(&self) -> usize {
        self.corrupted_rows.len()
    }
}

/// An ABFT-protected packed GEMM operand: B packed together with its
/// checksum column and group partial checksum columns, ready for repeated
/// protected multiplications.
#[derive(Clone, Debug)]
pub struct AbftGemm {
    pub packed: PackedB,
    pub modulus: i32,
    pub k: usize,
    pub n: usize,
    /// Column-group partial checksum columns carried by the pack
    /// (`group_count(n)`, or 0 for a legacy checksum-only pack — then
    /// [`AbftGemm::correct_row`] always declines).
    pub groups: usize,
}

impl AbftGemm {
    /// Encode + pack (Algorithm 1 lines 1-6, plus the PR-6 group partial
    /// checksum columns). Done once per weight matrix.
    pub fn new(b: &[i8], k: usize, n: usize) -> Self {
        Self::with_modulus(b, k, n, DEFAULT_MODULUS)
    }

    pub fn with_modulus(b: &[i8], k: usize, n: usize, modulus: i32) -> Self {
        let col = encode_checksum_col(b, k, n, modulus);
        let gcols = encode_group_checksum_cols(b, k, n, modulus);
        let mut extras: Vec<&[i8]> = Vec::with_capacity(1 + gcols.len());
        extras.push(&col);
        extras.extend(gcols.iter().map(|c| c.as_slice()));
        Self {
            packed: PackedB::pack_with_extra_cols(b, k, n, &extras),
            modulus,
            k,
            n,
            groups: gcols.len(),
        }
    }

    /// Wrap an already-packed encoded operand (used by fault campaigns that
    /// corrupt the packed bytes *after* encoding). Accepts both the full
    /// layout (checksum + group columns) and the legacy checksum-only one.
    pub fn from_packed(packed: PackedB, modulus: i32) -> Self {
        let (k, n) = (packed.k, packed.n);
        let groups = packed.extra_cols.checked_sub(1).expect("needs a checksum column");
        assert!(
            groups == 0 || groups == group_count(n),
            "extra columns must be 1 (legacy) or 1 + ⌈n/{GROUP_WIDTH}⌉"
        );
        Self {
            packed,
            modulus,
            k,
            n,
            groups,
        }
    }

    /// Total C_temp columns per row: payload + Eq-3b checksum + group
    /// partial checksums — the stride of every buffer this type touches.
    #[inline]
    pub fn n_total(&self) -> usize {
        self.n + 1 + self.groups
    }

    /// Protected GEMM (Algorithm 1 lines 7-16): compute
    /// `C_temp[m×n_total]` and verify every row. Returns the intermediate
    /// matrix (checksum columns included — requantization must exclude
    /// them) and the verdict.
    pub fn exec(&self, a: &[u8], m: usize) -> (Vec<i32>, Verdict) {
        let mut c = vec![0i32; m * self.n_total()];
        let verdict = self.exec_into(a, m, &mut c);
        (c, verdict)
    }

    /// Allocation-free variant for the serving hot path.
    pub fn exec_into(&self, a: &[u8], m: usize, c_temp: &mut [i32]) -> Verdict {
        gemm_exec_into(a, &self.packed, m, c_temp);
        self.verify(c_temp, m)
    }

    /// Check Eq 3b on an already-computed `C_temp[m×n_total]`.
    pub fn verify(&self, c_temp: &[i32], m: usize) -> Verdict {
        let nt = self.n_total();
        assert_eq!(c_temp.len(), m * nt);
        let mut corrupted_rows = Vec::new();
        for i in 0..m {
            let row = &c_temp[i * nt..(i + 1) * nt];
            if !row_ok(row, self.n, self.modulus) {
                corrupted_rows.push(i);
            }
        }
        Verdict { corrupted_rows }
    }

    /// Sampled Eq-3b verification: check only rows `i` with
    /// `(phase + i) % every == 0` — the policy layer's `Sampled(n)` mode.
    /// The caller advances `phase` by `m` per batch (a per-site counter),
    /// so coverage rotates across the row space instead of pinning to
    /// fixed indices. `every == 1` checks every row and is **identical**
    /// to [`AbftGemm::verify`] (property-tested in `rust/tests/prop.rs`).
    pub fn verify_sampled(&self, c_temp: &[i32], m: usize, every: u32, phase: u64) -> Verdict {
        let every = every.max(1) as u64;
        let nt = self.n_total();
        assert_eq!(c_temp.len(), m * nt);
        let mut corrupted_rows = Vec::new();
        let mut i = ((every - phase % every) % every) as usize;
        while i < m {
            if !row_ok(&c_temp[i * nt..(i + 1) * nt], self.n, self.modulus) {
                corrupted_rows.push(i);
            }
            i += every as usize;
        }
        Verdict { corrupted_rows }
    }

    /// How many rows [`AbftGemm::verify_sampled`] checks for a given
    /// batch height and phase (telemetry accounting; no verification).
    pub fn sampled_rows(m: usize, every: u32, phase: u64) -> usize {
        let every = every.max(1) as u64;
        let first = ((every - phase % every) % every) as usize;
        if first >= m {
            0
        } else {
            1 + (m - 1 - first) / every as usize
        }
    }

    /// Batch-aggregate Eq-3b: one congruence over the whole tile,
    /// `Σ_i (Σ_j C[i][j] − C[i][n]) ≡ 0 (mod modulus)` — the policy
    /// layer's `BoundOnly` mode. Strictly weaker than per-row
    /// verification: deltas on different rows can cancel mod `modulus`,
    /// and a failure cannot name the corrupted row (recovery is the
    /// engine's batch-level retry, not a row recompute). Returns `true`
    /// when the aggregate is clean.
    pub fn verify_aggregate(&self, c_temp: &[i32], m: usize) -> bool {
        self.aggregate_residual(c_temp, m) % self.modulus as i64 == 0
    }

    /// The raw tile residual `Σ_i (Σ_j C[i][j] − C[i][n])` the aggregate
    /// congruence tests — `≡ 0 (mod modulus)` on clean data, and shifted
    /// by exactly the injected delta under corruption (the difference of
    /// two residuals over the same inputs is mod-free).
    pub fn aggregate_residual(&self, c_temp: &[i32], m: usize) -> i64 {
        let nt = self.n_total();
        assert_eq!(c_temp.len(), m * nt);
        let mut t: i64 = 0;
        for i in 0..m {
            let row = &c_temp[i * nt..(i + 1) * nt];
            for &v in &row[..self.n] {
                t += v as i64;
            }
            t -= row[self.n] as i64;
        }
        t
    }

    /// The raw Eq-3b residual of one row, `Σ_j C[row][j] − C[row][n]` —
    /// `≡ 0 (mod modulus)` on any clean row. Taken before and after a
    /// row recompute, the residual shift is exactly the transient delta
    /// the fault injected (mod-free), which is the fault-event
    /// pipeline's severity signal.
    pub fn row_residual(&self, c_temp: &[i32], m: usize, row: usize) -> i64 {
        let nt = self.n_total();
        assert_eq!(c_temp.len(), m * nt);
        assert!(row < m);
        let r = &c_temp[row * nt..(row + 1) * nt];
        let mut t: i64 = 0;
        for &v in &r[..self.n] {
            t += v as i64;
        }
        t - r[self.n] as i64
    }

    /// Recompute the payload of a single corrupted row from A and the packed
    /// B (row-level recovery; the paper's deployment model is "recompute on
    /// detect" since double faults are vanishingly rare).
    pub fn recompute_row(&self, a: &[u8], row: usize, c_temp: &mut [i32], m: usize) {
        let nt = self.n_total();
        assert!(row < m);
        let arow = &a[row * self.k..(row + 1) * self.k];
        let out = &mut c_temp[row * nt..(row + 1) * nt];
        // One-row GEMM through the production kernel: same panel layout,
        // same bit-exact result as the original computation.
        crate::gemm::gemm_exec_into_scalar(arow, &self.packed, 1, out);
    }

    /// The raw group-`g` partial residual of one row,
    /// `Σ_{j ∈ group g} C[row][j] − C[row][n+1+g]` — `≡ 0 (mod modulus)`
    /// on a clean row; a non-zero residual names group `g` as corrupt.
    pub fn group_residual(&self, c_temp: &[i32], m: usize, row: usize, g: usize) -> i64 {
        let nt = self.n_total();
        assert_eq!(c_temp.len(), m * nt);
        assert!(row < m && g < self.groups);
        let r = &c_temp[row * nt..(row + 1) * nt];
        let j0 = g * GROUP_WIDTH;
        let j1 = self.n.min(j0 + GROUP_WIDTH);
        let mut t: i64 = 0;
        for &v in &r[j0..j1] {
            t += v as i64;
        }
        t - r[self.n + 1 + g] as i64
    }

    /// Localize the faulty column group of an Eq-3b-flagged row: returns
    /// `Some(g)` when exactly one group residual is non-zero mod
    /// `modulus`, `None` otherwise (clean, multi-group, or a fault in the
    /// Eq-3b checksum entry itself — which leaves every group residual
    /// clean because column `n` is outside all groups).
    pub fn localize_row(&self, c_temp: &[i32], m: usize, row: usize) -> Option<usize> {
        let md = self.modulus as i64;
        let mut hit = None;
        for g in 0..self.groups {
            if self.group_residual(c_temp, m, row, g) % md != 0 {
                if hit.is_some() {
                    return None;
                }
                hit = Some(g);
            }
        }
        hit
    }

    /// Algebraic in-place correction of a single Eq-3b-flagged row — the
    /// `CorrectInPlace` rung's mechanism. Intersects the row residual with
    /// the group residuals to name the faulty group, re-derives only that
    /// group's ≤[`GROUP_WIDTH`] candidate entries from A and the packed B
    /// (the mod-127 residual exposes δ only mod 127, so the exact corrupt
    /// value is pinned by a k-MAC column re-derivation — `GROUP_WIDTH/n`
    /// of a full row recompute), rewrites the one mismatching i32
    /// accumulator entry, and re-checks Eq 3b. If *no* group flags, the
    /// single-fault hypothesis puts the corruption in the checksum entry
    /// `C[row][n]` itself, and that lone column is the candidate set.
    ///
    /// Declines (leaving `c_temp` corrupt for the next rung) on any
    /// multi-fault signature and on operand faults, where re-derivation
    /// reads the same corrupt packed bytes and reproduces the stored
    /// values — see [`CorrectionDecline`].
    pub fn correct_row(&self, a: &[u8], row: usize, c_temp: &mut [i32], m: usize) -> RowCorrection {
        let nt = self.n_total();
        assert_eq!(c_temp.len(), m * nt);
        assert_eq!(a.len(), m * self.k);
        assert!(row < m);
        if self.groups == 0 {
            return RowCorrection::Declined(CorrectionDecline::NoGroups);
        }
        let md = self.modulus as i64;
        let mut flagged = None;
        for g in 0..self.groups {
            if self.group_residual(c_temp, m, row, g) % md != 0 {
                if flagged.is_some() {
                    return RowCorrection::Declined(CorrectionDecline::MultiGroup);
                }
                flagged = Some(g);
            }
        }
        let (j0, j1) = match flagged {
            Some(g) => (g * GROUP_WIDTH, self.n.min(g * GROUP_WIDTH + GROUP_WIDTH)),
            // Eq 3b fails but every group is clean: the corrupt entry is
            // the checksum column itself (single-fault hypothesis).
            None => (self.n, self.n + 1),
        };
        let arow = &a[row * self.k..(row + 1) * self.k];
        let mut fix: Option<(usize, i32)> = None;
        for j in j0..j1 {
            let want = self.rederive_entry(arow, j);
            if c_temp[row * nt + j] != want {
                if fix.is_some() {
                    return RowCorrection::Declined(CorrectionDecline::MultiMismatch);
                }
                fix = Some((j, want));
            }
        }
        let Some((col, want)) = fix else {
            return RowCorrection::Declined(CorrectionDecline::NoMismatch);
        };
        let delta = c_temp[row * nt + col] as i64 - want as i64;
        c_temp[row * nt + col] = want;
        if row_ok(&c_temp[row * nt..(row + 1) * nt], self.n, self.modulus) {
            RowCorrection::Corrected { col, delta }
        } else {
            // Beyond the single-corruption model: restore nothing (the
            // rewritten entry is provably the A·B value) but report the
            // failure so the caller recomputes the whole row.
            RowCorrection::Declined(CorrectionDecline::ReverifyFailed)
        }
    }

    /// Re-derive one logical C entry `A[row]·B[:, j]` by walking the
    /// packed column — i32 accumulation, bit-identical to every kernel
    /// dispatch path (integer adds commute).
    fn rederive_entry(&self, arow: &[u8], j: usize) -> i32 {
        let mut acc = 0i32;
        for p in 0..self.k {
            acc = acc.wrapping_add(arow[p] as i32 * self.packed.at(p, j) as i32);
        }
        acc
    }

    /// Theoretical FLOP overhead of encode+verify for one GEMM of shape
    /// (m, n, k): `1/(2m) + 1/n + 1/(2k)` (§IV-A1, encoding-B row).
    /// The PR-6 group checksum columns add `≈ 1/GROUP_WIDTH` of kernel
    /// work on top (`G/n` extra columns); see
    /// [`AbftGemm::localized_overhead`].
    pub fn theoretical_overhead(m: usize, n: usize, k: usize) -> f64 {
        1.0 / (2.0 * m as f64) + 1.0 / n as f64 + 1.0 / (2.0 * k as f64)
    }

    /// Theoretical overhead including the group partial checksum columns:
    /// the detect-only terms plus `G/n` extra kernel columns — still far
    /// inside the paper's <20% budget for DLRM shapes (≈ +3.2%).
    pub fn localized_overhead(m: usize, n: usize, k: usize) -> f64 {
        Self::theoretical_overhead(m, n, k) + group_count(n) as f64 / n as f64
    }
}

/// Row check: `Σ_j row[0..n] ≡ row[n] (mod modulus)`; i64 accumulation.
#[inline]
pub fn row_ok(row: &[i32], n: usize, modulus: i32) -> bool {
    let mut t: i64 = 0;
    for &v in &row[..n] {
        t += v as i64;
    }
    (t - row[n] as i64) % modulus as i64 == 0
}

/// §IV-A1 overhead if encoding A instead: `1/(2n) + 1/m + 1/(2k)`.
pub fn theoretical_overhead_encode_a(m: usize, n: usize, k: usize) -> f64 {
    1.0 / (2.0 * n as f64) + 1.0 / m as f64 + 1.0 / (2.0 * k as f64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn rand_ab(rng: &mut Pcg32, m: usize, k: usize, n: usize) -> (Vec<u8>, Vec<i8>) {
        let mut a = vec![0u8; m * k];
        let mut b = vec![0i8; k * n];
        rng.fill_u8(&mut a);
        rng.fill_i8(&mut b);
        (a, b)
    }

    #[test]
    fn clean_run_verifies_clean() {
        let mut rng = Pcg32::new(1);
        for &(m, k, n) in &[(1usize, 3200usize, 800usize), (4, 64, 64), (150, 256, 32)] {
            let (a, b) = rand_ab(&mut rng, m, k, n);
            let abft = AbftGemm::new(&b, k, n);
            let (_, verdict) = abft.exec(&a, m);
            assert!(verdict.clean(), "shape ({m},{k},{n})");
        }
    }

    #[test]
    fn payload_matches_unprotected_gemm() {
        let mut rng = Pcg32::new(2);
        let (m, k, n) = (5, 128, 40);
        let (a, b) = rand_ab(&mut rng, m, k, n);
        let abft = AbftGemm::new(&b, k, n);
        let nt = abft.n_total();
        let (c, _) = abft.exec(&a, m);
        let plain = crate::gemm::gemm_naive(&a, &b, m, k, n);
        for i in 0..m {
            assert_eq!(&c[i * nt..i * nt + n], &plain[i * n..(i + 1) * n]);
        }
    }

    #[test]
    fn detects_corruption_in_c() {
        let mut rng = Pcg32::new(3);
        let (m, k, n) = (8, 100, 50);
        let (a, b) = rand_ab(&mut rng, m, k, n);
        let abft = AbftGemm::new(&b, k, n);
        let nt = abft.n_total();
        let (mut c, _) = abft.exec(&a, m);
        // Flip a high bit in row 5.
        c[5 * nt + 7] ^= 1 << 20;
        let verdict = abft.verify(&c, m);
        assert_eq!(verdict.corrupted_rows, vec![5]);
    }

    #[test]
    fn multiple_corrupted_rows_all_reported() {
        let mut rng = Pcg32::new(4);
        let (m, k, n) = (10, 64, 30);
        let (a, b) = rand_ab(&mut rng, m, k, n);
        let abft = AbftGemm::new(&b, k, n);
        let nt = abft.n_total();
        let (mut c, _) = abft.exec(&a, m);
        for &r in &[1usize, 4, 9] {
            c[r * nt] ^= 1 << 10;
        }
        let verdict = abft.verify(&c, m);
        assert_eq!(verdict.corrupted_rows, vec![1, 4, 9]);
    }

    #[test]
    fn multiple_of_modulus_escapes_as_analyzed() {
        // An injected delta divisible by 127 is undetectable — the paper's
        // §IV-C false-negative condition, reproduced exactly.
        let mut rng = Pcg32::new(5);
        let (m, k, n) = (2, 16, 8);
        let (a, b) = rand_ab(&mut rng, m, k, n);
        let abft = AbftGemm::new(&b, k, n);
        let (mut c, _) = abft.exec(&a, m);
        c[3] += 127 * 5;
        assert!(abft.verify(&c, m).clean());
        c[3] += 1;
        assert!(!abft.verify(&c, m).clean());
    }

    #[test]
    fn recompute_row_repairs() {
        let mut rng = Pcg32::new(6);
        let (m, k, n) = (6, 80, 24);
        let (a, b) = rand_ab(&mut rng, m, k, n);
        let abft = AbftGemm::new(&b, k, n);
        let nt = abft.n_total();
        let (mut c, _) = abft.exec(&a, m);
        let clean = c.clone();
        c[2 * nt + 3] ^= 1 << 13;
        assert_eq!(abft.verify(&c, m).corrupted_rows, vec![2]);
        abft.recompute_row(&a, 2, &mut c, m);
        assert!(abft.verify(&c, m).clean());
        assert_eq!(c, clean);
    }

    #[test]
    fn correct_row_names_and_fixes_single_fault() {
        let mut rng = Pcg32::new(20);
        // n = 70: three groups, the last one ragged (width 6).
        let (m, k, n) = (6, 80, 70);
        let (a, b) = rand_ab(&mut rng, m, k, n);
        let abft = AbftGemm::new(&b, k, n);
        assert_eq!(abft.groups, group_count(n));
        let nt = abft.n_total();
        let (mut c, _) = abft.exec(&a, m);
        let clean = c.clone();
        for &(row, col) in &[(0usize, 0usize), (2, 33), (4, 69)] {
            c[row * nt + col] ^= 1 << 17;
            assert_eq!(abft.verify(&c, m).corrupted_rows, vec![row]);
            assert_eq!(abft.localize_row(&c, m, row), Some(col / GROUP_WIDTH));
            let got = abft.correct_row(&a, row, &mut c, m);
            assert_eq!(
                got,
                RowCorrection::Corrected { col, delta: (clean[row * nt + col] ^ (1 << 17)) as i64 - clean[row * nt + col] as i64 }
            );
            assert!(abft.verify(&c, m).clean());
            assert_eq!(c, clean, "corrected ≠ clean recompute at ({row},{col})");
        }
    }

    #[test]
    fn correct_row_fixes_checksum_entry_fault() {
        // Corruption in C[row][n] itself: Eq 3b flags, no group flags —
        // the checksum entry is the candidate and gets re-derived.
        let mut rng = Pcg32::new(21);
        let (m, k, n) = (4, 64, 40);
        let (a, b) = rand_ab(&mut rng, m, k, n);
        let abft = AbftGemm::new(&b, k, n);
        let nt = abft.n_total();
        let (mut c, _) = abft.exec(&a, m);
        let clean = c.clone();
        c[nt + n] += 9;
        assert_eq!(abft.verify(&c, m).corrupted_rows, vec![1]);
        assert_eq!(abft.localize_row(&c, m, 1), None);
        let got = abft.correct_row(&a, 1, &mut c, m);
        assert_eq!(got, RowCorrection::Corrected { col: n, delta: 9 });
        assert_eq!(c, clean);
    }

    #[test]
    fn correct_row_declines_multi_fault() {
        let mut rng = Pcg32::new(22);
        let (m, k, n) = (4, 48, 70);
        let (a, b) = rand_ab(&mut rng, m, k, n);
        let abft = AbftGemm::new(&b, k, n);
        let nt = abft.n_total();
        let (c0, _) = abft.exec(&a, m);

        // Two corrupt entries in different groups → MultiGroup.
        let mut c = c0.clone();
        c[2 * nt + 1] += 3;
        c[2 * nt + 40] += 5;
        assert_eq!(
            abft.correct_row(&a, 2, &mut c, m),
            RowCorrection::Declined(CorrectionDecline::MultiGroup)
        );

        // Two corrupt entries in the same group → MultiMismatch (the
        // group flags once, the candidate scan finds two bad slots).
        let mut c = c0.clone();
        c[2 * nt + 1] += 3;
        c[2 * nt + 2] += 5;
        assert_eq!(
            abft.correct_row(&a, 2, &mut c, m),
            RowCorrection::Declined(CorrectionDecline::MultiMismatch)
        );
        // The decline left the row corrupt for the next rung.
        assert_eq!(abft.verify(&c, m).corrupted_rows, vec![2]);
    }

    #[test]
    fn correct_row_declines_operand_fault() {
        // Corrupt the packed operand: C is consistent with the corrupt
        // bytes, so re-derivation reproduces the stored values exactly
        // and correction must decline (only recompute/failover helps).
        let mut rng = Pcg32::new(23);
        let (m, k, n) = (3, 32, 40);
        let (mut a, b) = rand_ab(&mut rng, m, k, n);
        a[5] = 1; // pin A[0][5] so the flipped B[5][7] is surely visible
        let mut abft = AbftGemm::new(&b, k, n);
        let off = abft.packed.offset(5, 7);
        abft.packed.data_mut()[off] ^= 0x40;
        let (mut c, verdict) = abft.exec(&a, m);
        assert!(!verdict.clean(), "operand corruption must be detected");
        let row = verdict.corrupted_rows[0];
        assert_eq!(
            abft.correct_row(&a, row, &mut c, m),
            RowCorrection::Declined(CorrectionDecline::NoMismatch)
        );
    }

    #[test]
    fn i64_rowsum_no_overflow_on_large_n() {
        // n*max_entry exceeds i32: entries near 2^27 with n=3200 would wrap
        // an i32 accumulator. Construct a saturated case.
        let (m, k, n) = (1usize, 3200usize, 3200usize);
        let a = vec![255u8; m * k];
        let b = vec![127i8; k * n];
        let abft = AbftGemm::new(&b, k, n);
        let (_, verdict) = abft.exec(&a, m);
        assert!(verdict.clean(), "saturated case must not false-positive");
    }

    #[test]
    fn checksum_col_values_fit_i8() {
        let mut rng = Pcg32::new(7);
        let (k, n) = (500, 333);
        let mut b = vec![0i8; k * n];
        rng.fill_i8(&mut b);
        let col = encode_checksum_col(&b, k, n, 127);
        for &v in &col {
            assert!((-127..=127).contains(&(v as i32)));
        }
    }

    #[test]
    fn theoretical_overhead_prefers_b_for_dlrm_shapes() {
        // DLRM: m small, n/k large → encoding B cheaper (§IV-A1).
        for &(m, n, k) in &[(1usize, 800usize, 3200usize), (100, 512, 512)] {
            assert!(
                AbftGemm::theoretical_overhead(m, n, k)
                    < theoretical_overhead_encode_a(m, n, k)
                    || m >= n
            );
        }
    }

    #[test]
    fn sampled_verify_checks_exactly_its_stripe() {
        let mut rng = Pcg32::new(8);
        let (m, k, n) = (12, 48, 20);
        let (a, b) = rand_ab(&mut rng, m, k, n);
        let abft = AbftGemm::new(&b, k, n);
        let nt = abft.n_total();
        let (mut c, _) = abft.exec(&a, m);
        // Corrupt every row: a sampled pass flags exactly its stripe.
        for r in 0..m {
            c[r * nt] ^= 1 << 9;
        }
        for every in [1u32, 2, 3, 4] {
            for phase in [0u64, 1, 5, 100] {
                let v = abft.verify_sampled(&c, m, every, phase);
                let expect: Vec<usize> =
                    (0..m).filter(|i| (phase + *i as u64) % every as u64 == 0).collect();
                assert_eq!(v.corrupted_rows, expect, "every={every} phase={phase}");
                assert_eq!(
                    AbftGemm::sampled_rows(m, every, phase),
                    expect.len(),
                    "count formula every={every} phase={phase}"
                );
            }
        }
    }

    #[test]
    fn aggregate_verify_catches_single_fault_and_admits_cancellation() {
        let mut rng = Pcg32::new(9);
        let (m, k, n) = (6, 32, 16);
        let (a, b) = rand_ab(&mut rng, m, k, n);
        let abft = AbftGemm::new(&b, k, n);
        let nt = abft.n_total();
        let (mut c, _) = abft.exec(&a, m);
        assert!(abft.verify_aggregate(&c, m), "clean tile must pass");
        c[3] += 5; // single fault → aggregate residue 5
        assert!(!abft.verify_aggregate(&c, m));
        // Opposing delta on another row cancels — the documented
        // weakness that makes BoundOnly the bottom of the checked lattice.
        c[2 * nt] -= 5;
        assert!(abft.verify_aggregate(&c, m));
        assert!(!abft.verify(&c, m).clean(), "per-row verify still catches it");
    }

    #[test]
    fn residuals_track_injected_deltas() {
        let mut rng = Pcg32::new(10);
        let (m, k, n) = (4, 32, 16);
        let (a, b) = rand_ab(&mut rng, m, k, n);
        let abft = AbftGemm::new(&b, k, n);
        let nt = abft.n_total();
        let (mut c, _) = abft.exec(&a, m);
        let base = abft.row_residual(&c, m, 2);
        assert_eq!(base % 127, 0, "clean row residual is ≡ 0 (mod 127)");
        let base_agg = abft.aggregate_residual(&c, m);
        assert_eq!(base_agg % 127, 0, "clean aggregate residual is ≡ 0 (mod 127)");
        c[2 * nt] += 5000;
        assert_eq!(abft.row_residual(&c, m, 2) - base, 5000);
        assert_eq!(
            abft.aggregate_residual(&c, m) - base_agg,
            5000,
            "aggregate residual carries the injected delta mod-free"
        );
    }

    #[test]
    fn requant_not_linear() {
        // §IV-B / E8: requantization is NOT linear, so checksums cannot be
        // carried through it: Q(a)+Q(b) != Q(a+b) in general.
        let qp = crate::quant::QParams::fit_u8(0.0, 100.0);
        let q = |x: f32| qp.quantize_u8(x) as i32;
        let mut violations = 0;
        for a in [3.3f32, 10.7, 55.1] {
            for b in [1.2f32, 9.9, 40.4] {
                if q(a) + q(b) != q(a + b) {
                    violations += 1;
                }
            }
        }
        assert!(violations > 0, "requantization unexpectedly linear");
    }
}
