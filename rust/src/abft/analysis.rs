//! Closed-form detection-probability analysis (paper §IV-C).
//!
//! Reproduces every bound derived in the paper for modulus 127 and
//! generalizes them to arbitrary prime moduli so the ablation benches can
//! compare policies. Each formula cites its paper paragraph; the
//! `detection_analysis` bench validates them against Monte-Carlo.

/// §IV-C1, fault model 1 (random bit flip in 8-bit B):
/// one row fails to witness the error iff `|A[p][i]| ∈ {0, 127, 254}` —
/// probability 3/256 per row; all m rows must fail.
/// `P(detect) = 1 - (3/256)^m`.
pub fn p_detect_bitflip_in_b(m: usize) -> f64 {
    1.0 - (3.0 / 256.0f64).powi(m as i32)
}

/// §IV-C1, fault model 2 (random data fluctuation in B):
/// per-row miss probability `(1*256 + 255*3 - 3) / (255*128) = 1018/32640`.
/// `P(detect) = 1 - (1018/32640)^m`.
pub fn p_detect_fluctuation_in_b(m: usize) -> f64 {
    1.0 - (1018.0 / 32640.0f64).powi(m as i32)
}

/// §IV-C2, fault model 1 (bit flip in 32-bit C_temp): the row-sum delta is
/// ±2^i, never divisible by 127 → certain detection.
pub fn p_detect_bitflip_in_c() -> f64 {
    1.0
}

/// §IV-C2, fault model 2 (fluctuation in C_temp): at most
/// `f(2^31 - 1) = (2^31 - 1)/mod` multiples of `mod` can hide the error →
/// `P(detect) ≥ 1 - 1/mod` (= 99.21% for 127).
pub fn p_detect_fluctuation_in_c_lower_bound(modulus: u32) -> f64 {
    1.0 - 1.0 / modulus as f64
}

/// Generalization of §IV-C1 model 1 to any odd prime modulus ≤ 127:
/// a bit flip in B changes it by ±2^l; by Euclid's lemma the product
/// `d·A[p][i]` is divisible by the prime iff `A[p][i]` is (2^l never is,
/// for odd mod). A[p][i] ∈ [0,255] has `count = ⌊255/mod⌋ + 1` multiples
/// of `mod` (including 0).
pub fn p_detect_bitflip_in_b_general(m: usize, modulus: u32) -> f64 {
    assert!(modulus % 2 == 1, "even modulus misses 2^l deltas");
    let multiples = (255 / modulus + 1) as f64;
    1.0 - (multiples / 256.0f64).powi(m as i32)
}

/// Exact per-row miss probability for §IV-C1 model 2 with any prime
/// modulus, by direct enumeration of (d, a) ∈ [1,255]×[0,255] pairs with
/// `d·a ≡ 0 (mod p)`. For 127 this reproduces the paper's 1018/32640
/// (the paper counts d ∈ [1,255] uniformly and divides by 255·128 — we
/// follow the same counting to land on the same constant).
pub fn per_row_miss_fluctuation_in_b(modulus: u32) -> f64 {
    let p = modulus;
    // Paper counting convention (§IV-C1 model 2): d counted over the i8
    // magnitude range [1,127] (one multiple of 127 → the "1*256" term),
    // a over [0,255] with ⌊255/p⌋+1 multiples (incl. 0 → the "255*3"
    // term), inclusion-exclusion overlap subtracted, denominator 255·128.
    let d_mult = (127 / p) as f64;
    let a_mult = (255 / p + 1) as f64;
    let misses = d_mult * 256.0 + 255.0 * a_mult - d_mult * a_mult;
    misses / (255.0 * 128.0)
}

pub fn p_detect_fluctuation_in_b_general(m: usize, modulus: u32) -> f64 {
    1.0 - per_row_miss_fluctuation_in_b(modulus).powi(m as i32)
}

/// §IV-C3: a computational error corrupts one partial product and behaves
/// exactly like a fluctuation in C_temp.
pub fn p_detect_compute_error_lower_bound(modulus: u32) -> f64 {
    p_detect_fluctuation_in_c_lower_bound(modulus)
}

/// True iff `n` is prime (tiny trial division — moduli are < 256).
pub fn is_prime(n: u32) -> bool {
    if n < 2 {
        return false;
    }
    let mut d = 2;
    while d * d <= n {
        if n % d == 0 {
            return false;
        }
        d += 1;
    }
    true
}

/// The paper's modulus choice argument (§IV-C): largest odd prime fitting
/// the i8 checksum lattice.
pub fn best_modulus_for_i8() -> u32 {
    (0..=127u32).rev().find(|&m| m % 2 == 1 && is_prime(m)).unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_constants_reproduced() {
        // §IV-C1: ≥ 98.83% already at m=1; paper's bound is the m=1 case.
        assert!((p_detect_bitflip_in_b(1) - (1.0 - 3.0 / 256.0)).abs() < 1e-12);
        assert!(p_detect_bitflip_in_b(1) >= 0.9883 - 1e-4);
        // §IV-C1 model 2: ≥ 96.89% at m=1.
        assert!((per_row_miss_fluctuation_in_b(127) - 1018.0 / 32640.0).abs() < 1e-12);
        assert!(p_detect_fluctuation_in_b(1) >= 0.9688);
        // §IV-C2 model 2: 1 - 1/127 = 99.21%.
        assert!((p_detect_fluctuation_in_c_lower_bound(127) - 0.99212598).abs() < 1e-6);
    }

    #[test]
    fn detection_improves_with_m() {
        assert!(p_detect_bitflip_in_b(10) > p_detect_bitflip_in_b(1));
        assert!(p_detect_fluctuation_in_b(100) > 0.999999);
    }

    #[test]
    fn general_reduces_to_paper_at_127() {
        for m in [1usize, 5, 50] {
            assert!((p_detect_bitflip_in_b_general(m, 127) - p_detect_bitflip_in_b(m)).abs() < 1e-12);
            assert!(
                (p_detect_fluctuation_in_b_general(m, 127) - p_detect_fluctuation_in_b(m)).abs()
                    < 1e-12
            );
        }
    }

    #[test]
    fn smaller_modulus_weaker() {
        assert!(p_detect_bitflip_in_b_general(1, 31) < p_detect_bitflip_in_b_general(1, 127));
        assert!(
            p_detect_fluctuation_in_c_lower_bound(31) < p_detect_fluctuation_in_c_lower_bound(127)
        );
    }

    #[test]
    fn best_modulus_is_127() {
        assert_eq!(best_modulus_for_i8(), 127);
        assert!(is_prime(127));
        assert!(!is_prime(125));
    }
}
