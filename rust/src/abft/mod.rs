//! Algorithm-based fault tolerance — the paper's contribution.
//!
//! * [`gemm`] — ABFT for low-precision GEMM (§IV, Algorithm 1).
//! * [`eb`] — ABFT for low-precision EmbeddingBag (§V, Algorithm 2).
//! * [`analysis`] — closed-form detection probabilities (§IV-C).
//! * [`baselines`] — rejected alternatives used as ablations (§II, §IV-A).

pub mod analysis;
pub mod baselines;
pub mod eb;
pub mod full;
pub mod gemm;
pub mod interaction;
pub mod scrub;

pub use eb::{
    CheckPrecision, EbCheck, EbChecksum, FusedEbAbft, FusedEbAbft4, RowMeta, DEFAULT_REL_BOUND,
};
pub use full::{CorrectionOutcome, FullAbftGemm};
pub use interaction::{protected_interaction, InteractionVerdict, INTERACTION_REL_BOUND};
pub use scrub::{ScrubReport, Scrubber};
pub use gemm::{
    encode_checksum_col, encode_group_checksum_cols, group_count, AbftGemm, CorrectionDecline,
    RowCorrection, Verdict, DEFAULT_MODULUS, GROUP_WIDTH,
};
