//! ABFT for low-precision EmbeddingBag (paper §V, Algorithm 2).
//!
//! One 32-bit *integer* row-sum column `C_T` is precomputed per table
//! (`C_T[i] = Σ_j codes[i][j]` — unscaled, to avoid accumulating float
//! round-off, §V-B). Per bag, the check is Eq 5:
//!
//! `Σ_j R[j]  ≈  Σ_{i∈I} w_i · (α_i · C_T[i] + d · β_i)`
//!
//! compared under a *relative round-off bound* (1e-5 in the paper §V-D —
//! deliberately loose: small float fluctuations don't move inference
//! results, so trading low-bit sensitivity for a low false-positive rate).
//!
//! # Dual checksum (PR 6)
//!
//! A plain row sum is blind to the §IV-C cancellation class: two intra-row
//! code corruptions of +δ/−δ preserve `Σ_j codes[i][j]` exactly. The
//! second per-row checksum `C_W[i] = Σ_j (j+1)·codes[i][j]` uses an
//! independent (index) weight vector, so the same corruption moves `C_W`
//! by `δ·(j₂−j₁) ≠ 0` — detectable. For a *single*-slot corruption the
//! pair also **localizes**: with `S = Σcodes − C_T` and
//! `W = Σ(j+1)·codes − C_W`, a lone fault at slot `j` gives `W = (j+1)·S`,
//! so `j = W/S − 1` and the original code is `current − S` — the scrubber
//! rewrites the slot and re-verifies both sums before re-admitting the
//! row (the R=1 self-heal; see [`EbChecksum::localize_slot`]).

use crate::embedding::{QuantTable4, QuantTable8};

/// Paper §V-D: relative bound separating round-off from soft error.
pub const DEFAULT_REL_BOUND: f64 = 1e-5;

/// The two sides of one Eq-5 comparison: the observed deviation and the
/// bound it is compared against. Carrying both (instead of collapsing to
/// a `bool`) lets the fault-event pipeline classify a flag's severity by
/// its margin ratio (`detect::Severity::from_eb_margin`) without
/// re-walking the bag.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct EbCheck {
    /// `|RSum − CSum|`.
    pub excess: f64,
    /// `rel_bound · bound_scale · max(|RSum|, |CSum|, 1)`.
    pub threshold: f64,
}

impl EbCheck {
    /// The Eq-5 verdict: `true` means a soft error is flagged.
    #[inline]
    pub fn flagged(&self) -> bool {
        self.excess > self.threshold
    }
}

/// Accumulation precision of the verifier sums.
///
/// The paper's implementation accumulates RSum/CSum in f32 — its own
/// round-off sits right at the 1e-5 bound, which is where Table III's
/// 9.5% false positives and 47% low-bit detection come from. This repo
/// defaults to f64 on the serving path (zero FPs at the same bound) and
/// uses [`CheckPrecision::F32`] in the campaign to reproduce the paper's
/// operating point. See DESIGN.md §Findings.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CheckPrecision {
    F32,
    F64,
}

/// Precomputed ABFT state for one embedding table.
#[derive(Clone, Debug)]
pub struct EbChecksum {
    /// Integer code row sums (the `C_T` column).
    pub c_t: Vec<i32>,
    /// Index-weighted integer code row sums (the `C_W` column):
    /// `C_W[i] = Σ_j (j+1)·codes[i][j]` — the independent-weight dual
    /// checksum that closes the sum-preserving cancellation class and
    /// localizes single-slot corruption (module docs).
    pub c_w: Vec<i32>,
    pub d: usize,
    pub rel_bound: f64,
    pub precision: CheckPrecision,
}

impl EbChecksum {
    /// Build from an 8-bit table (done once, offline — like the weight
    /// checksums, the table is trained and then immutable §V-C).
    pub fn build_8(table: &QuantTable8) -> Self {
        Self {
            c_t: (0..table.rows).map(|i| table.code_row_sum(i)).collect(),
            c_w: (0..table.rows).map(|i| table.weighted_code_row_sum(i)).collect(),
            d: table.d,
            rel_bound: DEFAULT_REL_BOUND,
            precision: CheckPrecision::F64,
        }
    }

    pub fn build_4(table: &QuantTable4) -> Self {
        Self {
            c_t: (0..table.rows).map(|i| table.code_row_sum(i)).collect(),
            c_w: (0..table.rows).map(|i| table.weighted_code_row_sum(i)).collect(),
            d: table.d,
            rel_bound: DEFAULT_REL_BOUND,
            precision: CheckPrecision::F64,
        }
    }

    pub fn with_bound(mut self, rel_bound: f64) -> Self {
        self.rel_bound = rel_bound;
        self
    }

    pub fn with_precision(mut self, precision: CheckPrecision) -> Self {
        self.precision = precision;
        self
    }

    /// Bytes of checksum storage (the §V-C `32/(p·d)` memory overhead
    /// per column; the PR 6 dual checksum stores two columns).
    pub fn bytes(&self) -> usize {
        (self.c_t.len() + self.c_w.len()) * 4
    }

    /// Exact integer deviation of one stored row from its canonical
    /// checksum: `code_row_sum(row) − C_T[row]`. Zero iff the row's
    /// code sum is intact; the magnitude is the scrub detector's
    /// severity signal (`detect::Severity::from_code_delta` — the
    /// Table-III high-/low-nibble significance split).
    pub fn row_delta(&self, table: &QuantTable8, row: usize) -> i64 {
        table.code_row_sum(row) as i64 - self.c_t[row] as i64
    }

    /// Exact integer deviation of the *index-weighted* sum from `C_W`:
    /// `Σ_j (j+1)·codes[row][j] − C_W[row]`. Independent of
    /// [`EbChecksum::row_delta`]'s weight vector, so sum-preserving
    /// intra-row corruption (which leaves `row_delta == 0`) still moves
    /// this one (module docs).
    pub fn weighted_row_delta(&self, table: &QuantTable8, row: usize) -> i64 {
        table.weighted_code_row_sum(row) as i64 - self.c_w[row] as i64
    }

    /// Both exact integer checks: `true` iff the stored row matches
    /// `C_T` **and** `C_W`. This is the re-admission gate after an
    /// in-place slot rewrite — a self-healed row is only served once
    /// both sums verify again.
    pub fn row_clean(&self, table: &QuantTable8, row: usize) -> bool {
        self.row_delta(table, row) == 0 && self.weighted_row_delta(table, row) == 0
    }

    /// Single-slot localization over a corrupt stored row (module docs):
    /// with `S = Σcodes − C_T` and `W = Σ(j+1)·codes − C_W`, a lone
    /// corrupt slot `j` satisfies `W = (j+1)·S`, so the slot is
    /// `W/S − 1` and its original code is `current − S`.
    ///
    /// Returns `Some((slot, original_code))` only when the residual pair
    /// resolves to exactly one in-range slot whose implied original is a
    /// valid byte. Returns `None` for a clean row, for corruption that
    /// spans multiple slots (non-divisible `W/S`, slot out of `0..d`, or
    /// implied original outside `0..=255`), and for the cancellation
    /// class (`S == 0, W ≠ 0` — detected but not localizable) — in every
    /// `None` case the caller falls down the recovery ladder
    /// (quarantine + repair from a replica) instead of rewriting.
    ///
    /// Note a multi-slot corruption can in principle alias a single-slot
    /// one; the rewrite is therefore always re-verified against **both**
    /// sums via [`EbChecksum::row_clean`] before the row is re-admitted,
    /// and an aliased rewrite that still fails verification falls
    /// through to quarantine unchanged-in-spirit (the slot write is
    /// reverted by the repair path's full-row rewrite).
    pub fn localize_slot(&self, table: &QuantTable8, row: usize) -> Option<(usize, u8)> {
        let s = self.row_delta(table, row);
        let w = self.weighted_row_delta(table, row);
        if s == 0 {
            // Clean (w == 0) or pure cancellation (w != 0): nothing a
            // single-slot rewrite can fix.
            return None;
        }
        if w % s != 0 {
            return None;
        }
        let q = w / s;
        if q < 1 || q > self.d as i64 {
            return None;
        }
        let j = (q - 1) as usize;
        let original = table.row(row)[j] as i64 - s;
        if !(0..=255).contains(&original) {
            return None;
        }
        Some((j, original as u8))
    }

    /// Checksum side of Eq 5 for one bag:
    /// `Σ_{i∈I} w_i (α_i C_T[i] + d β_i)`, accumulated per `precision`.
    pub fn expected_sum(
        &self,
        alpha: &[f32],
        beta: &[f32],
        indices: &[usize],
        weights: Option<&[f32]>,
    ) -> f64 {
        match self.precision {
            CheckPrecision::F64 => {
                let d = self.d as f64;
                let mut acc = 0f64;
                for (pos, &i) in indices.iter().enumerate() {
                    let w = weights.map_or(1.0, |w| w[pos]) as f64;
                    acc += w * (alpha[i] as f64 * self.c_t[i] as f64 + d * beta[i] as f64);
                }
                acc
            }
            CheckPrecision::F32 => {
                let d = self.d as f32;
                let mut acc = 0f32;
                for (pos, &i) in indices.iter().enumerate() {
                    let w = weights.map_or(1.0f32, |w| w[pos]);
                    acc += w * (alpha[i] * self.c_t[i] as f32 + d * beta[i]);
                }
                acc as f64
            }
        }
    }

    /// Algorithm 2 lines 2-7: verify one bag result `r` (len d).
    /// Returns `true` if a soft error is flagged.
    pub fn check_bag(
        &self,
        alpha: &[f32],
        beta: &[f32],
        indices: &[usize],
        weights: Option<&[f32]>,
        r: &[f32],
    ) -> bool {
        assert_eq!(r.len(), self.d);
        let rsum: f64 = match self.precision {
            CheckPrecision::F64 => r.iter().map(|&x| x as f64).sum(),
            CheckPrecision::F32 => r.iter().sum::<f32>() as f64,
        };
        let csum = self.expected_sum(alpha, beta, indices, weights);
        let scale = rsum.abs().max(csum.abs()).max(1.0);
        (rsum - csum).abs() > self.rel_bound * scale
    }

    /// Batched verification (offsets convention as in
    /// [`crate::embedding::embedding_bag_8`]): returns flagged bag indices.
    pub fn check_batch(
        &self,
        alpha: &[f32],
        beta: &[f32],
        indices: &[usize],
        offsets: &[usize],
        weights: Option<&[f32]>,
        result: &[f32],
    ) -> Vec<usize> {
        let batch = offsets.len();
        assert_eq!(result.len(), batch * self.d);
        let mut flagged = Vec::new();
        for b in 0..batch {
            let start = offsets[b];
            let end = if b + 1 < batch { offsets[b + 1] } else { indices.len() };
            let w = weights.map(|w| &w[start..end]);
            if self.check_bag(
                alpha,
                beta,
                &indices[start..end],
                w,
                &result[b * self.d..(b + 1) * self.d],
            ) {
                flagged.push(b);
            }
        }
        flagged
    }

    /// Build the cache-optimal fused layout (see [`FusedEbAbft`]).
    pub fn fuse(self, table: &QuantTable8) -> FusedEbAbft {
        FusedEbAbft::new(table, self)
    }

    /// §V-C FLOP overhead for a bag of `m` lookups: `(3m + d) / (3 m d)`.
    pub fn theoretical_overhead(m: usize, d: usize) -> f64 {
        1.0 / d as f64 + 1.0 / (3.0 * m as f64)
    }

    /// §V-C memory overhead fraction for a p-bit table: `32 / (p d)`.
    pub fn memory_overhead(p_bits: usize, d: usize) -> f64 {
        32.0 / (p_bits as f64 * d as f64)
    }
}

/// Per-row metadata interleaved for the fused protected bag: one 16-byte
/// record instead of three parallel arrays, so the row's α, β, C_T and
/// C_W arrive on a single cache line with one miss. The dual checksum
/// (PR 6) rides in what used to be the record's padding word — the
/// record size and the fused path's traffic are unchanged.
#[derive(Clone, Copy, Debug)]
#[repr(C)]
pub struct RowMeta {
    pub alpha: f32,
    pub beta: f32,
    pub c_t: i32,
    /// Index-weighted checksum (`C_W`) — not consulted by the Eq-5
    /// serving check (which needs only `C_T`), but kept resident so the
    /// scrubber's localization reads come from the same record.
    pub c_w: i32,
}

/// Cache-optimal protected EmbeddingBag (§Perf optimization).
///
/// The naive Algorithm-2 deployment re-walks the index list after the bag
/// to gather `C_T[i]` — with a cold multi-GB table that is one *extra
/// random cache miss per lookup* on top of the bag's own row fetch, which
/// measured at up to ~34% overhead for d=32 (vs the ~4% FLOP analysis).
/// `FusedEbAbft` (a) interleaves (α, β, C_T) in one record, so the
/// unprotected path's two metadata misses (α[], β[]) and the checksum's
/// C_T miss collapse into one, and (b) accumulates CSum *inside* the bag
/// loop while the record is hot. The protected bag then issues the same
/// number of random streams as the unprotected one.
#[derive(Clone, Debug)]
pub struct FusedEbAbft {
    pub meta: Vec<RowMeta>,
    pub d: usize,
    pub rel_bound: f64,
}

impl FusedEbAbft {
    pub fn new(table: &QuantTable8, checksum: EbChecksum) -> Self {
        assert_eq!(checksum.c_t.len(), table.rows);
        assert_eq!(checksum.c_w.len(), table.rows);
        let meta = (0..table.rows)
            .map(|i| RowMeta {
                alpha: table.alpha[i],
                beta: table.beta[i],
                c_t: checksum.c_t[i],
                c_w: checksum.c_w[i],
            })
            .collect();
        Self {
            meta,
            d: table.d,
            rel_bound: checksum.rel_bound,
        }
    }

    /// Fused protected bag: gather + reduce + Eq-5 verification in one
    /// pass. Returns `true` if the bag is flagged. `out` is zeroed first.
    ///
    /// The dequant-accumulate uses the same 8-wide AVX2 helper as the
    /// unprotected [`crate::embedding::bag_sum_8`] (bit-identical to
    /// scalar), and the CSum side keeps accumulating in the same gather
    /// pass while the (α, β, C_T) record is hot — the protected bag
    /// issues no extra sweep over the index list.
    pub fn bag_sum_checked(
        &self,
        table: &QuantTable8,
        indices: &[usize],
        weights: Option<&[f32]>,
        prefetch: bool,
        out: &mut [f32],
    ) -> bool {
        self.bag_sum_checked_scaled(table, indices, weights, prefetch, 1.0, out)
    }

    /// [`FusedEbAbft::bag_sum_checked`] with the Eq-5 relative bound
    /// scaled by `bound_scale` — the policy layer's `BoundOnly` mode
    /// relaxes the bound (scale ≫ 1) so only gross corruption flags,
    /// leaving low-significance faults to the scrubber's exact integer
    /// compare. `bound_scale == 1.0` is exactly the standard check, and
    /// the bag output is bit-identical for every scale (the bound only
    /// gates the verdict).
    pub fn bag_sum_checked_scaled(
        &self,
        table: &QuantTable8,
        indices: &[usize],
        weights: Option<&[f32]>,
        prefetch: bool,
        bound_scale: f64,
        out: &mut [f32],
    ) -> bool {
        self.bag_sum_checked_scaled_ex(table, indices, weights, prefetch, bound_scale, out)
            .flagged()
    }

    /// [`FusedEbAbft::bag_sum_checked_scaled`] returning the full
    /// [`EbCheck`] (deviation + bound) instead of only the verdict — the
    /// emission path's severity signal. The bag output and the verdict
    /// are bit-identical to the `bool` form; only the reporting is
    /// richer.
    pub fn bag_sum_checked_scaled_ex(
        &self,
        table: &QuantTable8,
        indices: &[usize],
        weights: Option<&[f32]>,
        prefetch: bool,
        bound_scale: f64,
        out: &mut [f32],
    ) -> EbCheck {
        let d = table.d;
        assert_eq!(d, self.d);
        assert_eq!(out.len(), d);
        out.fill(0.0);
        if let Some(w) = weights {
            assert_eq!(w.len(), indices.len());
        }
        let row_accum = crate::embedding::bag::select_axpb();
        let mut csum = 0f64;
        for (pos, &idx) in indices.iter().enumerate() {
            assert!(idx < table.rows, "index {idx} out of range");
            if prefetch {
                if let Some(&nxt) = indices.get(pos + crate::embedding::PREFETCH_DISTANCE) {
                    // Prefetch both the row and its meta record.
                    prefetch_bytes(&table.data, nxt * d);
                    prefetch_meta(&self.meta, nxt);
                }
            }
            let w = weights.map_or(1.0f32, |w| w[pos]);
            let m = self.meta[idx];
            let a = m.alpha * w;
            let b = m.beta * w;
            // CSum rides along while the meta record is in register.
            csum += (a * m.c_t as f32 + d as f32 * b) as f64;
            row_accum(out, table.row(idx), a, b);
        }
        let rsum: f64 = out.iter().map(|&x| x as f64).sum();
        let scale = rsum.abs().max(csum.abs()).max(1.0);
        EbCheck {
            excess: (rsum - csum).abs(),
            threshold: self.rel_bound * bound_scale * scale,
        }
    }

    pub fn bytes(&self) -> usize {
        self.meta.len() * std::mem::size_of::<RowMeta>()
    }
}

/// Fused protected bag over a 4-bit (nibble-packed) table — the paper's
/// §V-C p=4 configuration, where the checksum's relative memory overhead
/// doubles (32/(4d)) but the bag itself halves its traffic.
#[derive(Clone, Debug)]
pub struct FusedEbAbft4 {
    pub meta: Vec<RowMeta>,
    pub d: usize,
    pub rel_bound: f64,
}

impl FusedEbAbft4 {
    pub fn new(table: &QuantTable4, checksum: EbChecksum) -> Self {
        assert_eq!(checksum.c_t.len(), table.rows);
        assert_eq!(checksum.c_w.len(), table.rows);
        let meta = (0..table.rows)
            .map(|i| RowMeta {
                alpha: table.alpha[i],
                beta: table.beta[i],
                c_t: checksum.c_t[i],
                c_w: checksum.c_w[i],
            })
            .collect();
        Self {
            meta,
            d: table.d,
            rel_bound: checksum.rel_bound,
        }
    }

    /// Fused 4-bit protected bag; returns `true` if flagged.
    pub fn bag_sum_checked(
        &self,
        table: &QuantTable4,
        indices: &[usize],
        weights: Option<&[f32]>,
        out: &mut [f32],
    ) -> bool {
        let d = table.d;
        assert_eq!(d, self.d);
        assert_eq!(out.len(), d);
        out.fill(0.0);
        if let Some(w) = weights {
            assert_eq!(w.len(), indices.len());
        }
        let mut csum = 0f64;
        for (pos, &idx) in indices.iter().enumerate() {
            assert!(idx < table.rows, "index {idx} out of range");
            let w = weights.map_or(1.0f32, |w| w[pos]);
            let m = self.meta[idx];
            let a = m.alpha * w;
            let b = m.beta * w;
            csum += (a * m.c_t as f32 + d as f32 * b) as f64;
            for (j, o) in out.iter_mut().enumerate() {
                *o += a * table.code(idx, j) as f32 + b;
            }
        }
        let rsum: f64 = out.iter().map(|&x| x as f64).sum();
        let scale = rsum.abs().max(csum.abs()).max(1.0);
        (rsum - csum).abs() > self.rel_bound * scale
    }
}

#[inline]
fn prefetch_bytes(data: &[u8], offset: usize) {
    #[cfg(target_arch = "x86_64")]
    unsafe {
        if offset < data.len() {
            core::arch::x86_64::_mm_prefetch(
                data.as_ptr().add(offset) as *const i8,
                core::arch::x86_64::_MM_HINT_T0,
            );
        }
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        let _ = (data, offset);
    }
}

#[inline]
fn prefetch_meta(meta: &[RowMeta], idx: usize) {
    #[cfg(target_arch = "x86_64")]
    unsafe {
        if idx < meta.len() {
            core::arch::x86_64::_mm_prefetch(
                meta.as_ptr().add(idx) as *const i8,
                core::arch::x86_64::_MM_HINT_T0,
            );
        }
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        let _ = (meta, idx);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::embedding::{bag_sum_4, bag_sum_8};
    use crate::util::rng::Pcg32;

    fn setup(rows: usize, d: usize, seed: u64) -> (QuantTable8, EbChecksum, Pcg32) {
        let mut rng = Pcg32::new(seed);
        let table = QuantTable8::random(rows, d, &mut rng);
        let cs = EbChecksum::build_8(&table);
        (table, cs, rng)
    }

    #[test]
    fn clean_bag_passes() {
        let (table, cs, mut rng) = setup(10_000, 64, 41);
        for _ in 0..50 {
            let indices: Vec<usize> = (0..100).map(|_| rng.gen_range(0, 10_000)).collect();
            let mut r = vec![0f32; 64];
            bag_sum_8(&table, &indices, None, false, &mut r);
            assert!(!cs.check_bag(&table.alpha, &table.beta, &indices, None, &r));
        }
    }

    #[test]
    fn clean_weighted_bag_passes() {
        let (table, cs, mut rng) = setup(1000, 128, 42);
        let indices: Vec<usize> = (0..80).map(|_| rng.gen_range(0, 1000)).collect();
        let weights: Vec<f32> = (0..80).map(|_| rng.next_f32() * 2.0).collect();
        let mut r = vec![0f32; 128];
        bag_sum_8(&table, &indices, Some(&weights), false, &mut r);
        assert!(!cs.check_bag(&table.alpha, &table.beta, &indices, Some(&weights), &r));
    }

    #[test]
    fn high_bit_flip_in_result_detected() {
        let (table, cs, mut rng) = setup(1000, 64, 43);
        let indices: Vec<usize> = (0..100).map(|_| rng.gen_range(0, 1000)).collect();
        let mut r = vec![0f32; 64];
        bag_sum_8(&table, &indices, None, false, &mut r);
        // Flip a high mantissa/exponent bit of one output element.
        let bits = r[10].to_bits() ^ (1 << 28);
        r[10] = f32::from_bits(bits);
        assert!(cs.check_bag(&table.alpha, &table.beta, &indices, None, &r));
    }

    #[test]
    fn tiny_perturbation_below_bound_ignored() {
        // The loose bound is a *feature* (§V-D): sub-round-off fluctuations
        // must not trigger.
        let (table, cs, mut rng) = setup(1000, 64, 44);
        let indices: Vec<usize> = (0..100).map(|_| rng.gen_range(0, 1000)).collect();
        let mut r = vec![0f32; 64];
        bag_sum_8(&table, &indices, None, false, &mut r);
        r[3] += r[3].abs() * 1e-7;
        assert!(!cs.check_bag(&table.alpha, &table.beta, &indices, None, &r));
    }

    #[test]
    fn table_corruption_detected_via_result() {
        // Corrupt a code in the table AFTER checksums are built; the bag
        // computed from the corrupted table mismatches C_T.
        let (mut table, cs, mut rng) = setup(1000, 64, 45);
        let indices: Vec<usize> = (0..100).map(|_| rng.gen_range(0, 1000)).collect();
        let victim = indices[17];
        table.data[victim * 64 + 5] ^= 1 << 7; // high bit of a code
        let mut r = vec![0f32; 64];
        bag_sum_8(&table, &indices, None, false, &mut r);
        assert!(cs.check_bag(&table.alpha, &table.beta, &indices, None, &r));
    }

    #[test]
    fn batch_flags_only_corrupted_bag() {
        let (table, cs, mut rng) = setup(2000, 32, 46);
        let batch = 10;
        let per = 50;
        let indices: Vec<usize> = (0..batch * per).map(|_| rng.gen_range(0, 2000)).collect();
        let offsets: Vec<usize> = (0..batch).map(|b| b * per).collect();
        let mut result = crate::embedding::embedding_bag_8(&table, &indices, &offsets, None, false);
        let bits = result[7 * 32 + 3].to_bits() ^ (1 << 30);
        result[7 * 32 + 3] = f32::from_bits(bits);
        let flagged = cs.check_batch(&table.alpha, &table.beta, &indices, &offsets, None, &result);
        assert_eq!(flagged, vec![7]);
    }

    #[test]
    fn four_bit_table_checksum_works() {
        let mut rng = Pcg32::new(47);
        let table = QuantTable4::random(500, 48, &mut rng);
        let cs = EbChecksum::build_4(&table);
        let indices: Vec<usize> = (0..60).map(|_| rng.gen_range(0, 500)).collect();
        let mut r = vec![0f32; 48];
        bag_sum_4(&table, &indices, None, false, &mut r);
        assert!(!cs.check_bag(&table.alpha, &table.beta, &indices, None, &r));
        let bits = r[0].to_bits() ^ (1 << 27);
        r[0] = f32::from_bits(bits);
        assert!(cs.check_bag(&table.alpha, &table.beta, &indices, None, &r));
    }

    #[test]
    fn eq5_algebra_exact_in_f64() {
        // Verify the §V-B derivation directly: computing R in f64 makes both
        // sides of Eq 5 agree to ~1e-12 relative.
        let (table, cs, mut rng) = setup(300, 96, 48);
        let indices: Vec<usize> = (0..40).map(|_| rng.gen_range(0, 300)).collect();
        let mut r = vec![0f64; 96];
        for &i in &indices {
            let (a, b) = (table.alpha[i] as f64, table.beta[i] as f64);
            for (j, &q) in table.row(i).iter().enumerate() {
                r[j] += a * q as f64 + b;
            }
        }
        let rsum: f64 = r.iter().sum();
        let csum = cs.expected_sum(&table.alpha, &table.beta, &indices, None);
        assert!((rsum - csum).abs() <= 1e-9 * rsum.abs().max(1.0));
    }

    #[test]
    fn fused_bag_matches_unfused_and_detects() {
        let (table, cs, mut rng) = setup(2000, 64, 49);
        let fused = cs.clone().fuse(&table);
        for trial in 0..20 {
            let indices: Vec<usize> = (0..100).map(|_| rng.gen_range(0, 2000)).collect();
            let mut r_fused = vec![0f32; 64];
            let flagged = fused.bag_sum_checked(&table, &indices, None, trial % 2 == 0, &mut r_fused);
            assert!(!flagged, "clean fused bag flagged (trial {trial})");
            let mut r_plain = vec![0f32; 64];
            crate::embedding::bag_sum_8(&table, &indices, None, false, &mut r_plain);
            assert_eq!(r_fused, r_plain, "fused bag must be bitwise identical");
        }
        // Detection through the fused path: corrupt a touched row.
        let mut table2 = table.clone();
        let indices: Vec<usize> = (0..100).map(|_| rng.gen_range(0, 2000)).collect();
        table2.data[indices[3] * 64 + 7] ^= 0x80;
        let mut r = vec![0f32; 64];
        assert!(fused.bag_sum_checked(&table2, &indices, None, false, &mut r));
    }

    #[test]
    fn fused_weighted_matches() {
        let (table, cs, mut rng) = setup(500, 32, 50);
        let fused = cs.clone().fuse(&table);
        let indices: Vec<usize> = (0..40).map(|_| rng.gen_range(0, 500)).collect();
        let weights: Vec<f32> = (0..40).map(|_| rng.next_f32() + 0.5).collect();
        let mut r_fused = vec![0f32; 32];
        let flagged = fused.bag_sum_checked(&table, &indices, Some(&weights), true, &mut r_fused);
        assert!(!flagged);
        let mut r_plain = vec![0f32; 32];
        crate::embedding::bag_sum_8(&table, &indices, Some(&weights), false, &mut r_plain);
        assert_eq!(r_fused, r_plain);
    }

    #[test]
    fn dual_checksum_catches_sum_preserving_corruption() {
        // §IV-C cancellation class: +δ at one slot, −δ at another keeps
        // the plain row sum intact — row_delta is blind, the
        // index-weighted delta is not, and two-slot corruption must NOT
        // localize to a slot (else the "fix" would corrupt a third value).
        let (mut table, _, _) = setup(200, 64, 51);
        let row = 17;
        let base = row * 64;
        table.data[base + 9] = 100;
        table.data[base + 40] = 100;
        let cs = EbChecksum::build_8(&table);
        assert!(cs.row_clean(&table, row));
        table.data[base + 9] -= 5;
        table.data[base + 40] += 5;
        assert_eq!(cs.row_delta(&table, row), 0, "single checksum is blind");
        assert_ne!(cs.weighted_row_delta(&table, row), 0, "dual checksum flags");
        assert!(!cs.row_clean(&table, row));
        assert_eq!(cs.localize_slot(&table, row), None, "two-slot must not localize");
    }

    #[test]
    fn single_slot_corruption_localizes_and_heals() {
        let (mut table, cs, _) = setup(200, 64, 52);
        for &(row, slot, flip) in &[(3usize, 0usize, 0x01u8), (90, 63, 0x80), (150, 31, 0x42)] {
            let original = table.data[row * 64 + slot];
            table.data[row * 64 + slot] = original ^ flip;
            assert!(!cs.row_clean(&table, row));
            let (got_slot, got_original) =
                cs.localize_slot(&table, row).expect("single-slot fault localizes");
            assert_eq!((got_slot, got_original), (slot, original));
            // The R=1 self-heal: rewrite the named slot, both sums verify.
            table.data[row * 64 + got_slot] = got_original;
            assert!(cs.row_clean(&table, row));
            assert_eq!(cs.localize_slot(&table, row), None, "clean row localizes nothing");
        }
    }

    #[test]
    fn fused_meta_carries_both_checksums_in_16_bytes() {
        assert_eq!(std::mem::size_of::<RowMeta>(), 16);
        let (table, cs, _) = setup(50, 32, 53);
        let fused = cs.clone().fuse(&table);
        for i in 0..50 {
            assert_eq!(fused.meta[i].c_t, cs.c_t[i]);
            assert_eq!(fused.meta[i].c_w, cs.c_w[i]);
        }
        assert_eq!(cs.bytes(), 50 * 8, "dual checksum stores two i32 columns");
    }

    #[test]
    fn overhead_formulas() {
        assert!((EbChecksum::theoretical_overhead(100, 128) - (1.0 / 128.0 + 1.0 / 300.0)).abs() < 1e-12);
        assert!((EbChecksum::memory_overhead(8, 128) - 32.0 / 1024.0).abs() < 1e-12);
        assert!((EbChecksum::memory_overhead(4, 64) - 0.125).abs() < 1e-12);
    }
}
