//! The DLRM model assembled from the quantized operators (bottom MLP →
//! EmbeddingBags → pairwise interaction → top MLP), with ABFT protection
//! wired through every GEMM and EB.

pub mod config;
pub mod interaction;
pub mod layer;
pub mod model;
pub mod scratch;
pub mod serialize;

pub use config::{DlrmConfig, Protection, TableConfig};
pub use interaction::{interaction_dim, pairwise_interaction, pairwise_interaction_into};
pub use layer::{AbftLinear, LayerReport};
pub use model::{DlrmModel, DlrmRequest, EbStage, EbStageReport, InferenceReport, LocalEbStage};
pub use scratch::{EbScratch, InferenceScratch};
