//! ABFT-protected quantized fully-connected layer: the unit the DLRM MLPs
//! are composed of. Wraps the Alg-1 protected GEMM with requantization
//! (checksum column excluded, §IV-A3), quantized ReLU, and the
//! recompute-on-detect policy.

use crate::abft::{AbftGemm, Verdict};
use crate::detect::{
    recovery, Detector, Recovery, Resolution, Severity, SiteClass, SiteCtx, SiteId, UnitRef,
};
use crate::dlrm::config::Protection;
use crate::gemm::{gemm_requant_exec_into, PackedB};
use crate::obs::Stage;
use crate::policy::DetectionMode;
use crate::quant::{QParams, RequantEpilogue, RequantParams, RequantSpec};
use crate::util::rng::Pcg32;
use crate::util::scratch::{grow, GemmScratch};
use std::sync::Arc;
use std::time::Instant;

/// Detection/recovery events from one layer invocation.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct LayerReport {
    pub rows_flagged: usize,
    /// Rows fixed by the algebraic `CorrectInPlace` rung (group partial
    /// checksum localization; no recompute ran).
    pub rows_corrected: usize,
    pub rows_recomputed: usize,
}

impl LayerReport {
    pub fn merge(&mut self, other: &LayerReport) {
        self.rows_flagged += other.rows_flagged;
        self.rows_corrected += other.rows_corrected;
        self.rows_recomputed += other.rows_recomputed;
    }
}

/// Quantized FC layer with optional ABFT protection.
#[derive(Clone, Debug)]
pub struct AbftLinear {
    /// Protected operand (B packed with checksum column).
    abft: AbftGemm,
    /// Unprotected operand for `Protection::Off` (packed without checksum).
    plain: PackedB,
    pub w_qparams: QParams,
    pub out_qparams: QParams,
    /// Column sums of the weight payload, for requantization; `Arc`-shared
    /// into each forward's `RequantParams` instead of cloned per call.
    w_col_sums: Arc<[i32]>,
    pub k: usize,
    pub n: usize,
    pub relu: bool,
    pub protection: Protection,
}

impl AbftLinear {
    /// Build from float weights (k×n row-major).
    pub fn from_float(
        w: &[f32],
        k: usize,
        n: usize,
        out_range: (f32, f32),
        relu: bool,
        protection: Protection,
    ) -> Self {
        let (wq, w_qparams) = crate::quant::quantize_slice_i8(w);
        Self::from_quantized(&wq, w_qparams, k, n, out_range, relu, protection)
    }

    /// Random He-style initialization (synthetic models / benchmarks).
    pub fn random(
        k: usize,
        n: usize,
        relu: bool,
        protection: Protection,
        rng: &mut Pcg32,
    ) -> Self {
        let scale = (2.0 / k as f64).sqrt();
        let w: Vec<f32> = (0..k * n)
            .map(|_| (rng.next_gaussian() * scale) as f32)
            .collect();
        // Output range: He-init dot products over [0,~3] inputs have
        // std ≈ sqrt(2·E[x²]) ≈ O(1); ±4 covers ±3σ through the depth
        // without wasting lattice resolution (a sqrt(k)-wide range
        // quantizes every logit to the same code — scores collapse).
        // Deliberately asymmetric: a symmetric range puts the quantized
        // zero at code 127/128, and ReLU clamps most activations there —
        // code 127 ≡ 0 (mod 127) systematically hides downstream B-errors
        // (the §IV-C analysis assumes uniform A). Skewing the range moves
        // the zero code off the modulus. See DESIGN.md §Findings.
        let bound = 4.0f32;
        Self::from_float(&w, k, n, (-bound, bound * 1.10), relu, protection)
    }

    pub fn from_quantized(
        wq: &[i8],
        w_qparams: QParams,
        k: usize,
        n: usize,
        out_range: (f32, f32),
        relu: bool,
        protection: Protection,
    ) -> Self {
        let mut w_col_sums = vec![0i32; n];
        for p in 0..k {
            for j in 0..n {
                w_col_sums[j] += wq[p * n + j] as i32;
            }
        }
        Self {
            abft: AbftGemm::new(wq, k, n),
            plain: PackedB::pack(wq, k, n),
            w_qparams,
            out_qparams: QParams::fit_u8(out_range.0, out_range.1),
            w_col_sums: w_col_sums.into(),
            k,
            n,
            relu,
            protection,
        }
    }

    /// Forward one quantized batch (m×k u8). Returns (m×n u8, report).
    ///
    /// Allocating wrapper over [`AbftLinear::forward_into`] (kept for
    /// tests/tools); the serving path threads a [`GemmScratch`] through
    /// the `_into` form and never allocates.
    pub fn forward(&self, x: &[u8], m: usize, x_qparams: QParams) -> (Vec<u8>, LayerReport) {
        let mut scratch = GemmScratch::default();
        let mut out = vec![0u8; m * self.n];
        let report = self.forward_into(x, m, x_qparams, &mut scratch, &mut out);
        (out, report)
    }

    /// Allocation-free forward through the fused GEMM + requantize/ReLU
    /// kernel. The protected path computes `C_temp` (checksum column
    /// included) into `scratch.c_temp` *and* the quantized payload into
    /// `out` in one kernel pass, then verifies the stored i32 rows
    /// (Eq 3b semantics are unchanged — verification always sees the
    /// pre-requantization accumulator). A row that fails and is
    /// recomputed is re-requantized from its repaired accumulator, so
    /// the output is bit-identical to the two-pass requantize-after-
    /// recompute flow on every dispatch path.
    pub fn forward_into(
        &self,
        x: &[u8],
        m: usize,
        x_qparams: QParams,
        scratch: &mut GemmScratch,
        out: &mut [u8],
    ) -> LayerReport {
        self.forward_policied(
            x,
            m,
            x_qparams,
            DetectionMode::Full,
            SiteCtx::bare(None),
            scratch,
            out,
        )
    }

    /// [`AbftLinear::forward_into`] under an explicit [`DetectionMode`]
    /// (the policy layer's per-site dial). `Full` is exactly
    /// `forward_into`; `Sampled(n)` verifies 1-in-`n` rows (phase drawn
    /// from the site's telemetry so coverage rotates); `BoundOnly` runs
    /// one batch-aggregate congruence (a flag cannot name the row, so no
    /// local recompute happens — recovery is the engine's batch retry,
    /// reported as one flagged row); `Off` skips verification. Clean
    /// outputs are bit-identical across all modes — verification never
    /// writes the accumulator or the quantized payload.
    ///
    /// `site` is the layer's emission context ([`SiteCtx`]): its
    /// telemetry (units / verified units) is bumped when present, and
    /// every detection is emitted as a [`crate::detect::FaultEvent`]
    /// through the site's sink — severity classified from the Eq-3b
    /// residual magnitude, resolution from the recovery-ladder walk
    /// (`Recovered(RecomputeUnit)` when the row re-verifies after
    /// recompute, `Escalated(RetryBatch)` when the operand itself is
    /// corrupt and the engine's batch retry is the next applicable
    /// rung).
    pub fn forward_policied(
        &self,
        x: &[u8],
        m: usize,
        x_qparams: QParams,
        mode: DetectionMode,
        site: SiteCtx<'_>,
        scratch: &mut GemmScratch,
        out: &mut [u8],
    ) -> LayerReport {
        assert_eq!(x.len(), m * self.k, "input shape");
        assert_eq!(out.len(), m * self.n, "output shape");
        let mut report = LayerReport::default();
        let spec = RequantSpec::new(x_qparams, self.w_qparams, self.out_qparams, self.k);
        let relu_floor = if self.relu {
            self.out_qparams.quantize_u8(0.0)
        } else {
            0
        };
        let GemmScratch { c_temp, a_row_sums } = scratch;
        crate::gemm::row_sums_into(x, m, self.k, grow(a_row_sums, m));
        let epi = RequantEpilogue {
            spec,
            a_row_sums: &a_row_sums[..m],
            b_col_sums: &self.w_col_sums,
            n_out: self.n,
            relu_floor,
        };

        // One sampling decision covers the whole layer pass: the
        // operator span, the verify span, and the measured-overhead
        // EWMA all come from the same timed invocation (detached obs or
        // an unsampled pass takes no timestamps at all).
        let probe = site.obs.probe();
        let site_idx = match site.site {
            SiteId::Gemm(i) => i,
            SiteId::Eb(t) => t,
        };
        if probe.is_some() {
            // Stamp the dispatched kernel tier into the obs registry so
            // sampled traces and the engine's kernel block reflect what
            // actually ran (a few atomic/feature reads — alloc-free).
            site.obs.note_gemm_tier(site_idx, self.kernel_tier().code());
        }

        if self.protection.enabled() {
            let nt = self.abft.n_total();
            let c_temp = grow(c_temp, m * nt);
            let t_op = probe.map(|_| Instant::now());
            gemm_requant_exec_into(x, &self.abft.packed, m, &epi, c_temp, out);
            let op_ns = match (probe, t_op) {
                (Some(p), Some(t0)) => {
                    let ns = t0.elapsed().as_nanos() as u64;
                    p.span_ns(Stage::MlpLayer, site_idx, ns);
                    ns
                }
                _ => 0,
            };
            let mut rows_verified = m;
            let mut aggregate_flag = false;
            let t_verify = probe.map(|_| Instant::now());
            let verdict = match mode {
                DetectionMode::Full => self.abft.verify(c_temp, m),
                DetectionMode::Sampled(n) => {
                    let phase = site.telem.map_or(0, |t| t.sample_phase(m as u64));
                    rows_verified = AbftGemm::sampled_rows(m, n, phase);
                    self.abft.verify_sampled(c_temp, m, n, phase)
                }
                DetectionMode::BoundOnly => {
                    if !self.abft.verify_aggregate(c_temp, m) {
                        // The aggregate cannot localize: report one flag
                        // and leave recovery to the engine's batch retry.
                        aggregate_flag = true;
                        report.rows_flagged = 1;
                    }
                    Verdict { corrupted_rows: Vec::new() }
                }
                DetectionMode::Off => {
                    rows_verified = 0;
                    Verdict { corrupted_rows: Vec::new() }
                }
            };
            if let (Some(p), Some(t0)) = (probe, t_verify) {
                let verify_ns = t0.elapsed().as_nanos() as u64;
                p.span_ns(Stage::Verify, site_idx, verify_ns);
                // Feed the measured full-detection overhead only from
                // modes that ran the real per-row verify (BoundOnly's
                // aggregate check is a different, cheaper detector).
                if matches!(mode, DetectionMode::Full | DetectionMode::Sampled(_)) {
                    p.measured().note_gemm(
                        site_idx as usize,
                        op_ns,
                        verify_ns,
                        m as u64,
                        rows_verified as u64,
                    );
                }
            }
            report.rows_flagged += verdict.err_count();
            if let Some(t) = site.telem {
                t.record(m as u64, rows_verified as u64);
            }
            if aggregate_flag {
                // BoundOnly flag → the first applicable ladder rung is
                // the engine's batch retry (recompute cannot run without
                // a row to name). With no recompute reference the delta
                // magnitude cannot be bounded (the residual is only
                // meaningful mod 127), so classify worst-case.
                let resolution = if self.protection == Protection::DetectRecompute {
                    Resolution::escalated_or_degraded(recovery::first_step(
                        SiteClass::GemmAggregate,
                    ))
                } else {
                    Resolution::DetectedOnly
                };
                site.emit(
                    UnitRef::BatchAggregate,
                    Detector::GemmAggregate,
                    Severity::Significant,
                    resolution,
                );
            }
            let recompute = self.protection == Protection::DetectRecompute;
            for &row in &verdict.corrupted_rows {
                // Fault-path spans bypass the 1-in-n gate (probe_rare):
                // a once-per-outage rung would otherwise never sample.
                let rung_probe = site.obs.probe_rare();
                let t_rung = rung_probe.map(|_| Instant::now());
                let (severity, resolution) = if !recompute {
                    // Detect-only: no recompute reference, so the delta
                    // magnitude cannot be bounded — classify worst-case.
                    (Severity::Significant, Resolution::DetectedOnly)
                } else if let crate::abft::RowCorrection::Corrected { delta, .. } =
                    recovery::correct_gemm_row(&self.abft, x, row, m, &epi, c_temp, out)
                {
                    // CorrectInPlace rung: the group partial checksums
                    // localized the fault to one accumulator entry, the
                    // algebraic fix re-verified under Eq 3b, and the row
                    // was re-requantized — `delta` is exactly the
                    // corruption that would have been served.
                    report.rows_corrected += 1;
                    (
                        Severity::from_gemm_delta(delta),
                        Resolution::Recovered(Recovery::CorrectInPlace),
                    )
                } else {
                    report.rows_recomputed += 1;
                    // Correction declined (multi-fault or operand fault):
                    // fall to the RecomputeUnit rung. The recompute gives
                    // the severity reference: the residual shift across
                    // it IS the injected delta when the fault was
                    // transient.
                    let before = self.abft.row_residual(c_temp, m, row);
                    let ok = recovery::recompute_gemm_row(&self.abft, x, row, m, &epi, c_temp, out);
                    let after = self.abft.row_residual(c_temp, m, row);
                    if ok && after != before {
                        // Transient fault repaired: |before − after| is
                        // exactly the corruption that would have been
                        // served.
                        (
                            Severity::from_gemm_delta(before - after),
                            Resolution::Recovered(Recovery::RecomputeUnit),
                        )
                    } else {
                        // Recompute reproduced the flag — the operand
                        // itself is corrupt (magnitude unbounded ⇒
                        // Significant); escalate to the next rung.
                        (
                            Severity::Significant,
                            Resolution::escalated_or_degraded(recovery::next_step(
                                SiteClass::GemmRow,
                                Recovery::RecomputeUnit,
                            )),
                        )
                    }
                };
                if let (Some(p), Some(t0)) = (rung_probe, t_rung) {
                    if recompute {
                        // CorrectInPlace when the algebraic fix landed;
                        // otherwise the walk fell to (and ran) the
                        // RecomputeUnit rung.
                        let stage = if matches!(
                            resolution,
                            Resolution::Recovered(Recovery::CorrectInPlace)
                        ) {
                            Stage::CorrectInPlace
                        } else {
                            Stage::RecomputeUnit
                        };
                        p.span(stage, site_idx, t0);
                    }
                }
                site.emit(
                    UnitRef::GemmRow { row: row as u32 },
                    Detector::GemmChecksum,
                    severity,
                    resolution,
                );
            }
        } else {
            let c_temp = grow(c_temp, m * self.n);
            let t_op = probe.map(|_| Instant::now());
            gemm_requant_exec_into(x, &self.plain, m, &epi, c_temp, out);
            if let (Some(p), Some(t0)) = (probe, t_op) {
                p.span(Stage::MlpLayer, site_idx, t0);
            }
        }
        report
    }

    /// Expose the 32-bit intermediate for fault-injection tests.
    pub fn forward_raw(&self, x: &[u8], m: usize) -> (Vec<i32>, crate::abft::Verdict) {
        self.abft.exec(x, m)
    }

    /// The Eq-1 requantization parameter set for one input batch (used by
    /// tests and baselines that drive the two-pass scalar path).
    pub fn requant_params(&self, x: &[u8], m: usize, x_qparams: QParams) -> RequantParams {
        let mut a_row_sums = vec![0i32; m];
        crate::gemm::row_sums_into(x, m, self.k, &mut a_row_sums);
        RequantParams {
            a: x_qparams,
            b: self.w_qparams,
            c: self.out_qparams,
            a_row_sums,
            b_col_sums: Arc::clone(&self.w_col_sums),
            k: self.k,
        }
    }

    /// The GEMM kernel tier the dispatcher selects for this layer on
    /// this host — a function of CPU features, the active pack's
    /// pack-time acc16 certificate, the layer's k, and any tier cap
    /// (env/override). Output bytes are identical on every tier; this
    /// exists for observability (`metrics_snapshot`'s `kernel` block).
    pub fn kernel_tier(&self) -> crate::gemm::KernelTier {
        let packed = if self.protection.enabled() {
            &self.abft.packed
        } else {
            &self.plain
        };
        crate::gemm::select_tier(packed)
    }

    /// Packed-weight bytes (protected layout).
    pub fn weight_bytes(&self) -> usize {
        self.abft.packed.bytes()
    }

    /// Direct access for fault injection in integration tests.
    pub fn abft_mut(&mut self) -> &mut AbftGemm {
        &mut self.abft
    }

    pub fn abft(&self) -> &AbftGemm {
        &self.abft
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quantize_input(rng: &mut Pcg32, m: usize, k: usize) -> (Vec<u8>, QParams) {
        let xf: Vec<f32> = (0..m * k).map(|_| rng.next_f32()).collect();
        crate::quant::quantize_slice_u8(&xf)
    }

    #[test]
    fn protected_and_unprotected_agree_when_clean() {
        let mut rng = Pcg32::new(81);
        let (m, k, n) = (8, 64, 32);
        let mut layer = AbftLinear::random(k, n, true, Protection::DetectRecompute, &mut rng);
        let (x, xp) = quantize_input(&mut rng, m, k);
        let (y_prot, rep) = layer.forward(&x, m, xp);
        assert_eq!(rep, LayerReport::default());
        layer.protection = Protection::Off;
        let (y_plain, _) = layer.forward(&x, m, xp);
        assert_eq!(y_prot, y_plain, "ABFT must be output-transparent");
    }

    #[test]
    fn relu_clamps_negatives() {
        let mut rng = Pcg32::new(82);
        let (m, k, n) = (4, 32, 16);
        let layer = AbftLinear::random(k, n, true, Protection::Detect, &mut rng);
        let (x, xp) = quantize_input(&mut rng, m, k);
        let (y, _) = layer.forward(&x, m, xp);
        let zero_code = layer.out_qparams.quantize_u8(0.0);
        assert!(y.iter().all(|&v| v >= zero_code));
    }

    #[test]
    fn detect_recompute_repairs_corrupted_weights_effect() {
        // Corrupt packed B after encoding → verdict flags rows → with
        // DetectRecompute the *recomputed* output still reflects the
        // corrupted weights (B itself is wrong), but detection fires.
        let mut rng = Pcg32::new(83);
        let (m, k, n) = (6, 48, 24);
        let mut layer = AbftLinear::random(k, n, false, Protection::Detect, &mut rng);
        #[allow(unused_variables)] let (x, xp) = quantize_input(&mut rng, m, k);
        // flip a payload bit in packed B (logical (5,3) via the panel map)
        let idx = layer.abft().packed.offset(5, 3);
        let data = layer.abft_mut().packed.data_mut();
        data[idx] = (data[idx] as u8 ^ 0x40) as i8;
        let (_, rep) = layer.forward(&x, m, xp);
        assert!(rep.rows_flagged > 0, "corrupted weight must be flagged");
    }

    #[test]
    fn recompute_fixes_transient_c_errors() {
        let mut rng = Pcg32::new(84);
        let (m, k, n) = (5, 40, 20);
        let layer = AbftLinear::random(k, n, false, Protection::DetectRecompute, &mut rng);
        let (x, _xp) = quantize_input(&mut rng, m, k);
        let (mut c_temp, verdict) = layer.forward_raw(&x, m);
        assert!(verdict.clean());
        let nt = layer.abft().n_total();
        let clean = c_temp.clone();
        c_temp[2 * nt + 4] ^= 1 << 19;
        let v2 = layer.abft().verify(&c_temp, m);
        assert_eq!(v2.corrupted_rows, vec![2]);
        layer.abft().recompute_row(&x, 2, &mut c_temp, m);
        assert_eq!(c_temp, clean);
    }

    #[test]
    fn policied_forward_corrects_in_place_and_matches_clean_output() {
        // A transient single-entry fault injected into the shared scratch
        // is corrected by the CorrectInPlace rung: the served bytes equal
        // the clean forward bit-for-bit and the report shows a correction,
        // not a recompute. (End-to-end single-fault flows are covered by
        // the correction campaign; this pins the layer-level walk.)
        let mut rng = Pcg32::new(85);
        let (m, k, n) = (5, 40, 20);
        let layer = AbftLinear::random(k, n, false, Protection::DetectRecompute, &mut rng);
        let (x, xp) = quantize_input(&mut rng, m, k);
        let (clean_y, rep) = layer.forward(&x, m, xp);
        assert_eq!(rep, LayerReport::default());
        let (mut c_temp, _) = layer.forward_raw(&x, m);
        let nt = layer.abft().n_total();
        c_temp[3 * nt + 11] ^= 1 << 21;
        // Drive the correction rung directly over the corrupt tile.
        let params = layer.requant_params(&x, m, xp);
        let epi = RequantEpilogue {
            spec: RequantSpec::new(xp, layer.w_qparams, layer.out_qparams, k),
            a_row_sums: &params.a_row_sums,
            b_col_sums: &params.b_col_sums,
            n_out: n,
            relu_floor: 0,
        };
        let mut out = clean_y.clone();
        let got = recovery::correct_gemm_row(layer.abft(), &x, 3, m, &epi, &mut c_temp, &mut out);
        assert!(got.corrected(), "single fault must correct: {got:?}");
        assert!(layer.abft().verify(&c_temp, m).clean());
        assert_eq!(out, clean_y, "corrected row must re-requantize to the clean bytes");
    }
}
