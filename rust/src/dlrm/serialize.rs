//! Model snapshot format (`.dlrm` files): save/load a quantized DLRM so
//! the launcher can serve a fixed model (`dlrm-abft serve --model-path`)
//! and so corrupted tables can be re-fetched from the store after a
//! scrubber hit (the fail-stop/recovery loop the paper defers to
//! checkpoint-restart [1]).
//!
//! Format: little-endian, section-per-component, each section protected
//! by a CRC-32 — a model store for a soft-error paper should notice its
//! own bit rot. ABFT checksums (packed B′ column, C_T, fused meta) are
//! NOT stored: they are re-encoded on load, so the encode path is always
//! exercised and a stale checksum can never mask a corrupted payload.

use crate::dlrm::config::{DlrmConfig, Protection};
use crate::dlrm::layer::AbftLinear;
use crate::dlrm::model::DlrmModel;
use crate::embedding::QuantTable8;
use crate::quant::QParams;
use anyhow::{anyhow, bail, Context, Result};
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 8] = b"DLRMABF1";
const VERSION: u32 = 1;

/// Table-driven CRC-32 (IEEE 802.3 polynomial) — no crc crate offline.
pub fn crc32(data: &[u8]) -> u32 {
    static TABLE: std::sync::OnceLock<[u32; 256]> = std::sync::OnceLock::new();
    let table = TABLE.get_or_init(|| {
        let mut t = [0u32; 256];
        for (i, e) in t.iter_mut().enumerate() {
            let mut c = i as u32;
            for _ in 0..8 {
                c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            }
            *e = c;
        }
        t
    });
    let mut crc = 0xFFFF_FFFFu32;
    for &b in data {
        crc = table[((crc ^ b as u32) & 0xFF) as usize] ^ (crc >> 8);
    }
    crc ^ 0xFFFF_FFFF
}

struct SectionWriter<W: Write> {
    w: W,
}

impl<W: Write> SectionWriter<W> {
    fn section(&mut self, tag: &[u8; 4], payload: &[u8]) -> Result<()> {
        self.w.write_all(tag)?;
        self.w.write_all(&(payload.len() as u64).to_le_bytes())?;
        self.w.write_all(&crc32(payload).to_le_bytes())?;
        self.w.write_all(payload)?;
        Ok(())
    }
}

struct SectionReader<R: Read> {
    r: R,
}

impl<R: Read> SectionReader<R> {
    fn section(&mut self, expect_tag: &[u8; 4]) -> Result<Vec<u8>> {
        let mut tag = [0u8; 4];
        self.r.read_exact(&mut tag)?;
        if &tag != expect_tag {
            bail!(
                "section tag mismatch: expected {:?}, got {:?}",
                std::str::from_utf8(expect_tag),
                std::str::from_utf8(&tag)
            );
        }
        let mut len8 = [0u8; 8];
        self.r.read_exact(&mut len8)?;
        let len = u64::from_le_bytes(len8) as usize;
        let mut crc4 = [0u8; 4];
        self.r.read_exact(&mut crc4)?;
        let want = u32::from_le_bytes(crc4);
        let mut payload = vec![0u8; len];
        self.r.read_exact(&mut payload)?;
        let got = crc32(&payload);
        if got != want {
            bail!(
                "CRC mismatch in section {:?}: stored {want:#010x}, computed {got:#010x} — \
                 snapshot is corrupted",
                std::str::from_utf8(expect_tag)
            );
        }
        Ok(payload)
    }
}

fn push_f32(buf: &mut Vec<u8>, x: f32) {
    buf.extend_from_slice(&x.to_le_bytes());
}

fn push_u64(buf: &mut Vec<u8>, x: u64) {
    buf.extend_from_slice(&x.to_le_bytes());
}

struct Cursor<'a> {
    data: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.data.len() {
            bail!("truncated section");
        }
        let s = &self.data[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
}

fn encode_layer(l: &AbftLinear) -> Vec<u8> {
    let mut buf = Vec::new();
    push_u64(&mut buf, l.k as u64);
    push_u64(&mut buf, l.n as u64);
    buf.push(l.relu as u8);
    push_f32(&mut buf, l.w_qparams.alpha);
    push_f32(&mut buf, l.w_qparams.beta);
    push_f32(&mut buf, l.out_qparams.alpha);
    push_f32(&mut buf, l.out_qparams.beta);
    // Payload weights only (k×n), re-materialized row-major from the
    // panel-interleaved pack (checksum column dropped).
    let packed = &l.abft().packed;
    for p in 0..l.k {
        buf.extend((0..l.n).map(|j| packed.at(p, j) as u8));
    }
    buf
}

fn decode_layer(payload: &[u8], protection: Protection) -> Result<AbftLinear> {
    let mut c = Cursor { data: payload, pos: 0 };
    let k = c.u64()? as usize;
    let n = c.u64()? as usize;
    let relu = c.take(1)?[0] != 0;
    let w_qparams = QParams { alpha: c.f32()?, beta: c.f32()? };
    let out_alpha = c.f32()?;
    let out_beta = c.f32()?;
    let wq: Vec<i8> = c.take(k * n)?.iter().map(|&v| v as i8).collect();
    let mut layer = AbftLinear::from_quantized(
        &wq,
        w_qparams,
        k,
        n,
        (out_beta, out_beta + out_alpha * 255.0),
        relu,
        protection,
    );
    // from_quantized refits the lattice from the range; restore exactly.
    layer.out_qparams = QParams { alpha: out_alpha, beta: out_beta };
    Ok(layer)
}

fn encode_table(t: &QuantTable8) -> Vec<u8> {
    let mut buf = Vec::new();
    push_u64(&mut buf, t.rows as u64);
    push_u64(&mut buf, t.d as u64);
    buf.extend_from_slice(&t.data);
    for &a in &t.alpha {
        push_f32(&mut buf, a);
    }
    for &b in &t.beta {
        push_f32(&mut buf, b);
    }
    buf
}

fn decode_table(payload: &[u8]) -> Result<QuantTable8> {
    let mut c = Cursor { data: payload, pos: 0 };
    let rows = c.u64()? as usize;
    let d = c.u64()? as usize;
    let data = c.take(rows * d)?.to_vec();
    let mut alpha = Vec::with_capacity(rows);
    for _ in 0..rows {
        alpha.push(c.f32()?);
    }
    let mut beta = Vec::with_capacity(rows);
    for _ in 0..rows {
        beta.push(c.f32()?);
    }
    Ok(QuantTable8 { rows, d, data, alpha, beta })
}

impl DlrmModel {
    /// Write a snapshot to `path`.
    pub fn save<P: AsRef<Path>>(&self, path: P) -> Result<()> {
        let f = std::fs::File::create(path.as_ref())
            .with_context(|| format!("creating {}", path.as_ref().display()))?;
        let mut w = SectionWriter { w: std::io::BufWriter::new(f) };

        let mut head = Vec::new();
        head.extend_from_slice(MAGIC);
        head.extend_from_slice(&VERSION.to_le_bytes());
        w.section(b"HEAD", &head)?;

        // Config as JSON (human-inspectable with xxd).
        let cfg = &self.cfg;
        let cfg_json = crate::util::json::Json::obj(vec![
            ("num_dense", crate::util::json::Json::Num(cfg.num_dense as f64)),
            ("embedding_dim", crate::util::json::Json::Num(cfg.embedding_dim as f64)),
            (
                "bottom_mlp",
                crate::util::json::Json::Arr(
                    cfg.bottom_mlp.iter().map(|&h| crate::util::json::Json::Num(h as f64)).collect(),
                ),
            ),
            (
                "top_mlp",
                crate::util::json::Json::Arr(
                    cfg.top_mlp.iter().map(|&h| crate::util::json::Json::Num(h as f64)).collect(),
                ),
            ),
            (
                "tables",
                crate::util::json::Json::Arr(
                    cfg.tables
                        .iter()
                        .map(|t| {
                            crate::util::json::Json::obj(vec![
                                ("rows", crate::util::json::Json::Num(t.rows as f64)),
                                ("pooling", crate::util::json::Json::Num(t.pooling as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
            ("seed", crate::util::json::Json::Num(cfg.seed as f64)),
        ]);
        w.section(b"CONF", cfg_json.to_string().as_bytes())?;

        let mut qp = Vec::new();
        push_f32(&mut qp, self.dense_qparams.alpha);
        push_f32(&mut qp, self.dense_qparams.beta);
        push_f32(&mut qp, self.top_qparams.alpha);
        push_f32(&mut qp, self.top_qparams.beta);
        push_f32(&mut qp, cfg.dense_range.0);
        push_f32(&mut qp, cfg.dense_range.1);
        w.section(b"QPAR", &qp)?;

        // Calibrated per-column standardization of the top-MLP input.
        let mut stdz = Vec::new();
        push_u64(&mut stdz, self.top_mean.len() as u64);
        for &m in &self.top_mean {
            push_f32(&mut stdz, m);
        }
        for &sd in &self.top_std {
            push_f32(&mut stdz, sd);
        }
        w.section(b"STDZ", &stdz)?;

        for l in self.bottom.iter() {
            w.section(b"LBOT", &encode_layer(l))?;
        }
        for l in self.top.iter() {
            w.section(b"LTOP", &encode_layer(l))?;
        }
        w.section(b"LHED", &encode_layer(&self.head))?;
        for t in &self.tables {
            w.section(b"TABL", &encode_table(t))?;
        }
        w.section(b"TAIL", b"end")?;
        Ok(())
    }

    /// Load a snapshot; ABFT state (checksum column, C_T, fused meta) is
    /// re-encoded from the payloads.
    pub fn load<P: AsRef<Path>>(path: P, protection: Protection) -> Result<DlrmModel> {
        let f = std::fs::File::open(path.as_ref())
            .with_context(|| format!("opening {}", path.as_ref().display()))?;
        let mut r = SectionReader { r: std::io::BufReader::new(f) };

        let head = r.section(b"HEAD")?;
        if &head[..8] != MAGIC {
            bail!("not a dlrm-abft snapshot");
        }
        let version = u32::from_le_bytes(head[8..12].try_into().unwrap());
        if version != VERSION {
            bail!("unsupported snapshot version {version}");
        }

        let conf = r.section(b"CONF")?;
        let conf_json = crate::util::json::Json::parse(
            std::str::from_utf8(&conf).map_err(|_| anyhow!("CONF not utf8"))?,
        )?;
        let mut cfg = DlrmConfig::from_json(&conf_json)?;
        cfg.protection = protection;

        let qp = r.section(b"QPAR")?;
        let mut c = Cursor { data: &qp, pos: 0 };
        let dense_qparams = QParams { alpha: c.f32()?, beta: c.f32()? };
        let top_qparams = QParams { alpha: c.f32()?, beta: c.f32()? };
        cfg.dense_range = (c.f32()?, c.f32()?);

        let stdz = r.section(b"STDZ")?;
        let mut c = Cursor { data: &stdz, pos: 0 };
        let dim = c.u64()? as usize;
        if dim != cfg.top_input_dim() {
            bail!("STDZ dim {dim} != top_input_dim {}", cfg.top_input_dim());
        }
        let mut top_mean = Vec::with_capacity(dim);
        for _ in 0..dim {
            top_mean.push(c.f32()?);
        }
        let mut top_std = Vec::with_capacity(dim);
        for _ in 0..dim {
            top_std.push(c.f32()?);
        }

        let mut bottom = Vec::new();
        for _ in 0..cfg.bottom_mlp.len() {
            bottom.push(decode_layer(&r.section(b"LBOT")?, protection)?);
        }
        let mut top = Vec::new();
        for _ in 0..cfg.top_mlp.len() {
            top.push(decode_layer(&r.section(b"LTOP")?, protection)?);
        }
        let head_layer = decode_layer(&r.section(b"LHED")?, protection)?;
        let mut tables = Vec::new();
        let mut checksums = Vec::new();
        let mut fused = Vec::new();
        for tc in &cfg.tables {
            let table = decode_table(&r.section(b"TABL")?)?;
            if table.rows != tc.rows || table.d != cfg.embedding_dim {
                bail!("table shape mismatch vs CONF");
            }
            let cs = crate::abft::EbChecksum::build_8(&table);
            fused.push(cs.clone().fuse(&table));
            checksums.push(cs);
            tables.push(table);
        }
        r.section(b"TAIL")?;

        Ok(DlrmModel {
            cfg,
            bottom,
            top,
            head: head_layer,
            tables,
            checksums,
            fused,
            dense_qparams,
            top_qparams,
            top_mean,
            top_std,
            policy: crate::policy::PolicyHandle::default(),
            events: crate::detect::EventSink::detached(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dlrm::config::TableConfig;
    use crate::util::rng::Pcg32;

    fn tiny() -> DlrmModel {
        DlrmModel::random(DlrmConfig {
            num_dense: 4,
            embedding_dim: 8,
            bottom_mlp: vec![16, 8],
            top_mlp: vec![16],
            tables: vec![
                TableConfig { rows: 100, pooling: 5 },
                TableConfig { rows: 50, pooling: 3 },
            ],
            protection: Protection::DetectRecompute,
            dense_range: (0.0, 1.0),
            seed: 31,
        })
    }

    #[test]
    fn crc32_known_vector() {
        // CRC-32/IEEE of "123456789" is 0xCBF43926.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn save_load_roundtrip_scores_identical() {
        let model = tiny();
        let dir = std::env::temp_dir().join("dlrm_abft_test_snapshot.dlrm");
        model.save(&dir).unwrap();
        let loaded = DlrmModel::load(&dir, Protection::DetectRecompute).unwrap();
        let mut rng = Pcg32::new(1);
        let reqs = model.synth_requests(6, &mut rng);
        let (s1, r1) = model.forward(&reqs);
        let (s2, r2) = loaded.forward(&reqs);
        assert_eq!(s1, s2, "loaded model must score identically");
        assert_eq!(r1, r2);
        assert!(r2.clean());
        std::fs::remove_file(&dir).ok();
    }

    #[test]
    fn corrupted_snapshot_rejected() {
        let model = tiny();
        let path = std::env::temp_dir().join("dlrm_abft_test_corrupt.dlrm");
        model.save(&path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x10;
        std::fs::write(&path, &bytes).unwrap();
        let err = match DlrmModel::load(&path, Protection::Detect) {
            Err(e) => e,
            Ok(_) => panic!("corrupted snapshot loaded successfully"),
        };
        let msg = format!("{err:#}");
        assert!(
            msg.contains("CRC") || msg.contains("tag") || msg.contains("truncated"),
            "unexpected error: {msg}"
        );
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn load_with_different_protection_mode() {
        let model = tiny();
        let path = std::env::temp_dir().join("dlrm_abft_test_prot.dlrm");
        model.save(&path).unwrap();
        let loaded = DlrmModel::load(&path, Protection::Off).unwrap();
        assert_eq!(loaded.cfg.protection, Protection::Off);
        let mut rng = Pcg32::new(2);
        let reqs = model.synth_requests(3, &mut rng);
        let (s1, _) = model.forward(&reqs);
        let (s2, _) = loaded.forward(&reqs);
        assert_eq!(s1, s2, "protection mode must not change scores");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn truncated_file_rejected() {
        let model = tiny();
        let path = std::env::temp_dir().join("dlrm_abft_test_trunc.dlrm");
        model.save(&path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() / 3]).unwrap();
        assert!(DlrmModel::load(&path, Protection::Detect).is_err());
        std::fs::remove_file(&path).ok();
    }
}
