//! DLRM pairwise feature interaction: dot products between every pair of
//! the (num_tables + 1) d-dimensional feature vectors (bottom-MLP output +
//! one pooled embedding per table).

/// `vectors` is `groups` feature vectors per sample, laid out as
/// `batch × groups × d`. Output is `batch × C(groups,2)` of pairwise dots
/// (upper triangle, row-major pair order).
pub fn pairwise_interaction(vectors: &[f32], batch: usize, groups: usize, d: usize) -> Vec<f32> {
    let pairs = interaction_dim(groups);
    let mut out = vec![0f32; batch * pairs];
    pairwise_interaction_into(vectors, batch, groups, d, &mut out);
    out
}

/// Allocation-free form of [`pairwise_interaction`]: writes the
/// `batch × C(groups,2)` dots into a caller-provided buffer (the serving
/// path reuses its scratch arena's).
pub fn pairwise_interaction_into(
    vectors: &[f32],
    batch: usize,
    groups: usize,
    d: usize,
    out: &mut [f32],
) {
    assert_eq!(vectors.len(), batch * groups * d);
    let pairs = interaction_dim(groups);
    assert_eq!(out.len(), batch * pairs);
    for b in 0..batch {
        let base = b * groups * d;
        let mut p = 0;
        for g1 in 0..groups {
            let v1 = &vectors[base + g1 * d..base + (g1 + 1) * d];
            for g2 in (g1 + 1)..groups {
                let v2 = &vectors[base + g2 * d..base + (g2 + 1) * d];
                let mut dot = 0f32;
                for j in 0..d {
                    dot += v1[j] * v2[j];
                }
                out[b * pairs + p] = dot;
                p += 1;
            }
        }
    }
}

/// Number of interaction features for `groups` vectors.
pub fn interaction_dim(groups: usize) -> usize {
    groups * (groups - 1) / 2
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn two_vectors_single_dot() {
        // batch=1, groups=2, d=3: [1,2,3]·[4,5,6] = 32
        let v = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        assert_eq!(pairwise_interaction(&v, 1, 2, 3), vec![32.0]);
    }

    #[test]
    fn pair_order_and_count() {
        // groups=3 → pairs (0,1), (0,2), (1,2)
        let v = [
            1.0, 0.0, // g0
            0.0, 1.0, // g1
            1.0, 1.0, // g2
        ];
        let out = pairwise_interaction(&v, 1, 3, 2);
        assert_eq!(out, vec![0.0, 1.0, 1.0]);
        assert_eq!(interaction_dim(3), 3);
    }

    #[test]
    fn batch_independence() {
        let mut v = vec![0f32; 2 * 2 * 4];
        // batch 0: ones; batch 1: twos.
        for x in &mut v[..8] {
            *x = 1.0;
        }
        for x in &mut v[8..] {
            *x = 2.0;
        }
        let out = pairwise_interaction(&v, 2, 2, 4);
        assert_eq!(out, vec![4.0, 16.0]);
    }
}
