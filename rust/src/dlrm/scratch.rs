//! The inference scratch arena: every buffer a `DlrmModel::forward_into`
//! pass needs, owned in one reusable struct so steady-state serving makes
//! **zero heap allocations** (ROADMAP "Zero-allocation pipeline").
//!
//! # Ownership / aliasing rules
//!
//! * One arena belongs to **one forward pass at a time**. The engine keeps
//!   a pool of arenas and checks one out per batch (per worker thread
//!   under the shared read lock), so concurrent scoring never shares an
//!   arena. Nothing here is `Sync`-guarded — sharing mid-pass is a bug.
//! * All buffers are grow-only ([`grow`]): the first batch at the largest
//!   shapes is the warmup allocation; afterwards `Engine::score` is
//!   allocation-free (enforced by the counting-allocator test in
//!   `rust/tests/zero_alloc.rs`).
//! * Contents are stale between passes. Each stage fully overwrites the
//!   prefix it claims before anything reads it; no stage may read a
//!   region another stage wrote during a *previous* pass.
//! * The activation pair `act_a`/`act_b` ping-pongs through the MLP
//!   chains by `std::mem::swap` — pointers move, bytes never copy.

use crate::dlrm::model::EbStageReport;
pub use crate::util::scratch::{grow, GemmScratch};

/// Scratch owned by the EmbeddingBag serving strategy ([`EbStage`]).
/// [`LocalEbStage`] needs none; the shard router parks its per-shard
/// fan-out buffers here so they pool across batches instead of being
/// reallocated per batch (ROADMAP shard open item).
///
/// [`EbStage`]: crate::dlrm::EbStage
/// [`LocalEbStage`]: crate::dlrm::LocalEbStage
#[derive(Clone, Debug, Default)]
pub struct EbScratch {
    /// One dense `batch × shard_slots × d` buffer per shard (indexed by
    /// shard id). Grown lazily to the store's shard count.
    pub bufs: Vec<Vec<f32>>,
    /// One detection tally per shard, reset each run.
    pub reports: Vec<EbStageReport>,
}

impl EbScratch {
    /// Make sure at least `n` per-shard buffer/report slots exist and
    /// reset the first `n` reports. Allocation-free once `n` has been
    /// seen (the empty `Vec`s themselves are pooled).
    pub fn reset(&mut self, n: usize) {
        while self.bufs.len() < n {
            self.bufs.push(Vec::new());
        }
        if self.reports.len() < n {
            self.reports.resize(n, EbStageReport::default());
        }
        self.reports[..n].fill(EbStageReport::default());
    }
}

/// All buffers of one end-to-end forward pass (see module docs for the
/// ownership rules). Stage map:
///
/// | field       | written by                  | read by                  |
/// |-------------|-----------------------------|--------------------------|
/// | `act_a/b`   | quantize + every MLP layer  | the next layer           |
/// | `gemm`      | each layer's fused GEMM     | ABFT verify / recompute  |
/// | `bottom_f`  | bottom-MLP dequantization   | feats slot 0, top concat |
/// | `feats`     | slot 0 copy + EB stage      | pairwise interaction     |
/// | `inter`     | pairwise interaction        | top-MLP concat           |
/// | `top_in`    | concat                      | top-MLP quantization     |
/// | `eb`        | the EB stage strategy       | (strategy-internal)      |
#[derive(Clone, Debug, Default)]
pub struct InferenceScratch {
    /// Per-layer GEMM accumulator + A-row sums (shared down the chain).
    pub gemm: GemmScratch,
    /// Quantized activation ping buffer (holds the current layer input).
    pub act_a: Vec<u8>,
    /// Quantized activation pong buffer (receives the layer output).
    pub act_b: Vec<u8>,
    /// Dequantized bottom-MLP output, `batch × d`.
    pub bottom_f: Vec<f32>,
    /// Feature groups `batch × (1 + num_tables) × d`.
    pub feats: Vec<f32>,
    /// Pairwise interactions `batch × C(groups, 2)`.
    pub inter: Vec<f32>,
    /// Top-MLP float input `batch × top_input_dim`.
    pub top_in: Vec<f32>,
    /// EB-stage strategy scratch (shard router fan-out buffers).
    pub eb: EbScratch,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eb_scratch_reset_pools_slots() {
        let mut eb = EbScratch::default();
        eb.reset(3);
        assert_eq!(eb.bufs.len(), 3);
        assert_eq!(eb.reports.len(), 3);
        eb.reports[1].flagged = 7;
        grow(&mut eb.bufs[2], 16);
        eb.reset(2);
        assert_eq!(eb.bufs.len(), 3, "buffers are pooled, not dropped");
        assert_eq!(eb.reports[1], EbStageReport::default(), "reports reset");
        assert_eq!(eb.bufs[2].len(), 16, "capacity survives reset");
    }
}
