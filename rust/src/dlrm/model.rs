//! The DLRM inference model assembled from the quantized operators, with
//! ABFT protection on every GEMM and EmbeddingBag (the paper's two >70%
//! latency operators) and a recompute-on-detect recovery policy.

use crate::abft::{EbChecksum, FusedEbAbft};
use crate::detect::{
    recovery, Detector, EventSink, Recovery, Resolution, Severity, SiteClass, SiteCtx, SiteId,
    UnitRef, LOCAL_REPLICA,
};
use crate::dlrm::config::{DlrmConfig, Protection};
use crate::dlrm::interaction::pairwise_interaction_into;
use crate::dlrm::layer::{AbftLinear, LayerReport};
use crate::dlrm::scratch::{grow, EbScratch, GemmScratch, InferenceScratch};
use crate::embedding::{bag_sum_8, QuantTable8};
use crate::obs::{ObsHandle, Stage};
use crate::policy::PolicyHandle;
use crate::quant::QParams;
use crate::util::rng::Pcg32;
use crate::util::threadpool::EB_PAR_MIN_WORK;
use std::sync::Mutex;
use std::time::Instant;

/// One inference request: dense features + per-table index lists.
#[derive(Clone, Debug)]
pub struct DlrmRequest {
    pub dense: Vec<f32>,
    /// `sparse[t]` = lookup indices into table t.
    pub sparse: Vec<Vec<usize>>,
}

/// Aggregated soft-error events from one batch inference.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct InferenceReport {
    pub gemm: LayerReport,
    pub eb_bags_flagged: usize,
    pub eb_bags_recomputed: usize,
    /// Flagged again after recompute — a persistent (memory) error.
    pub eb_bags_unrecovered: usize,
    /// Shard-router events (sharded serving only). Under
    /// `DetectRecompute` these were already recovered inside the EB
    /// stage — by retry or replica failover — so they do NOT dirty the
    /// batch; only `eb_bags_flagged`/`eb_bags_unrecovered` do (and
    /// detect-only flags are mirrored into `eb_bags_flagged`).
    pub shard_detections: usize,
    pub shard_failovers: usize,
    pub shard_quarantines: usize,
}

impl InferenceReport {
    pub fn merge(&mut self, o: &InferenceReport) {
        self.gemm.merge(&o.gemm);
        self.eb_bags_flagged += o.eb_bags_flagged;
        self.eb_bags_recomputed += o.eb_bags_recomputed;
        self.eb_bags_unrecovered += o.eb_bags_unrecovered;
        self.shard_detections += o.shard_detections;
        self.shard_failovers += o.shard_failovers;
        self.shard_quarantines += o.shard_quarantines;
    }

    pub fn clean(&self) -> bool {
        self.gemm.rows_flagged == 0 && self.eb_bags_flagged == 0
    }
}

/// Detection tallies from one EB-stage execution (local or sharded).
/// `flagged`/`recomputed`/`unrecovered` follow the local detect →
/// recompute-once semantics; the `shard_*` counters record router
/// events. Under `DetectRecompute` those events were recovered
/// transparently (retry or failover — they never reach a served value);
/// under detect-only protection a flagged bag is ALSO counted in
/// `flagged` and its value is served as-is, mirroring the local stage.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct EbStageReport {
    pub flagged: usize,
    pub recomputed: usize,
    pub unrecovered: usize,
    pub shard_detections: usize,
    pub shard_failovers: usize,
    pub shard_quarantines: usize,
}

impl EbStageReport {
    pub fn absorb(&mut self, o: &EbStageReport) {
        self.flagged += o.flagged;
        self.recomputed += o.recomputed;
        self.unrecovered += o.unrecovered;
        self.shard_detections += o.shard_detections;
        self.shard_failovers += o.shard_failovers;
        self.shard_quarantines += o.shard_quarantines;
    }
}

/// Strategy for the EmbeddingBag stage of the forward pass: fill every
/// request's table slots (1..=T) of the `batch × (1+T) × d` feature
/// buffer — slot 0 already holds the bottom-MLP output — and report
/// detection tallies. [`LocalEbStage`] reads the model's own tables; the
/// shard router ([`crate::shard::ShardRouter`]) serves the same traffic
/// from a replicated shard store with detection-driven failover.
///
/// `eb` is the caller's pooled stage scratch: implementations park any
/// per-batch buffers there (grow-only) so steady-state serving stays
/// allocation-free; [`LocalEbStage`] needs none and ignores it.
///
/// Contract: on clean data an implementation must be **bit-identical**
/// to [`LocalEbStage`] — a model's scores must not depend on the serving
/// topology.
pub trait EbStage: Sync {
    fn run(
        &self,
        model: &DlrmModel,
        requests: &[DlrmRequest],
        feats: &mut [f32],
        eb: &mut EbScratch,
    ) -> EbStageReport;
}

/// The unsharded EB stage: every table served from `model.tables`,
/// request-parallel on the global pool.
pub struct LocalEbStage;

impl EbStage for LocalEbStage {
    fn run(
        &self,
        model: &DlrmModel,
        requests: &[DlrmRequest],
        feats: &mut [f32],
        _eb: &mut EbScratch,
    ) -> EbStageReport {
        let d = model.cfg.embedding_dim;
        let groups = model.tables.len() + 1;
        let eb_work: usize = requests
            .iter()
            .flat_map(|r| r.sparse.iter())
            .map(|s| s.len() * d)
            .sum();
        // Each request owns a disjoint (1+T)·d feature row, so requests
        // fan out on the global pool with bit-identical results; tallies
        // are summed per job and folded once (order-independent).
        let total = Mutex::new(EbStageReport::default());
        crate::util::threadpool::global().scope_chunks(
            feats,
            groups * d,
            eb_work,
            EB_PAR_MIN_WORK,
            |req0, chunk| {
                let mut local = EbStageReport::default();
                for (bi, fchunk) in chunk.chunks_mut(groups * d).enumerate() {
                    model.eb_for_request(req0 + bi, &requests[req0 + bi], fchunk, &mut local);
                }
                total.lock().unwrap().absorb(&local);
            },
        );
        total.into_inner().unwrap()
    }
}

/// The model: quantized bottom/top MLPs + quantized embedding tables.
pub struct DlrmModel {
    pub cfg: DlrmConfig,
    pub bottom: Vec<AbftLinear>,
    pub top: Vec<AbftLinear>,
    pub head: AbftLinear,
    pub tables: Vec<QuantTable8>,
    pub checksums: Vec<EbChecksum>,
    /// Cache-optimal fused ABFT state (one per table); the serving path
    /// uses this instead of the naive bag+check (see abft::eb §Perf note).
    pub fused: Vec<FusedEbAbft>,
    pub dense_qparams: QParams,
    /// Static (calibrated) quantization lattice for the top-MLP input.
    /// Dynamic per-batch quantization would make a request's score depend
    /// on which batch it landed in — unacceptable for serving.
    pub top_qparams: QParams,
    /// Per-column standardization of the top-MLP input, fitted at
    /// calibration. Interaction features are O(pooling²·d) while MLP
    /// features are O(1); without standardization the shared u8 lattice
    /// wastes its range and the head saturates.
    pub top_mean: Vec<f32>,
    pub top_std: Vec<f32>,
    /// Adaptive-detection attachment ([`crate::policy`]): per-site mode
    /// cells + telemetry, written by `Engine::with_policy`. Detached by
    /// default — every site then behaves as `Full`, bit-identical to the
    /// pre-policy model. GEMM site order is bottom layers, top layers,
    /// head; EB sites are global table ids.
    pub policy: PolicyHandle,
    /// Fault-event emission handle ([`crate::detect`]): every detection
    /// this model's sites raise flows through here to the journal,
    /// policy telemetry, and serving metrics. Detached by default (a
    /// standalone model emits nothing); the engine attaches its sink at
    /// construction, and the shard store inherits it.
    pub events: EventSink,
    /// Span-profiler handle ([`crate::obs`]): pipeline stages and
    /// detection verifies time themselves through here when sampling is
    /// on. Detached by default (every probe is one branch); the engine
    /// attaches one at construction, and the shard store inherits it.
    pub obs: ObsHandle,
}

impl DlrmModel {
    /// Synthetic random model from a config (weights He-initialized then
    /// quantized; tables uniform-random as in the paper's evaluation).
    pub fn random(cfg: DlrmConfig) -> Self {
        let mut rng = Pcg32::new(cfg.seed);
        let prot = cfg.protection;
        let mut bottom = Vec::new();
        let mut prev = cfg.num_dense;
        for &h in &cfg.bottom_mlp {
            bottom.push(AbftLinear::random(prev, h, true, prot, &mut rng));
            prev = h;
        }
        let mut top = Vec::new();
        let mut tprev = cfg.top_input_dim();
        for &h in &cfg.top_mlp {
            top.push(AbftLinear::random(tprev, h, true, prot, &mut rng));
            tprev = h;
        }
        let head = AbftLinear::random(tprev, 1, false, prot, &mut rng);
        let mut tables = Vec::new();
        let mut checksums = Vec::new();
        let mut fused = Vec::new();
        for t in &cfg.tables {
            let table = QuantTable8::random(t.rows, cfg.embedding_dim, &mut rng);
            let cs = EbChecksum::build_8(&table);
            fused.push(cs.clone().fuse(&table));
            checksums.push(cs);
            tables.push(table);
        }
        let dense_qparams = QParams::fit_u8(cfg.dense_range.0, cfg.dense_range.1);
        let mut model = Self {
            cfg,
            bottom,
            top,
            head,
            tables,
            checksums,
            fused,
            dense_qparams,
            top_qparams: QParams::fit_u8(-1.0, 1.0), // placeholder
            top_mean: Vec::new(),
            top_std: Vec::new(),
            policy: PolicyHandle::default(),
            events: EventSink::detached(),
            obs: ObsHandle::detached(),
        };
        model.calibrate(&mut rng);
        model
    }

    /// Post-training static-quantization calibration: run a synthetic batch
    /// through the bottom half and fit the top-MLP input lattice with
    /// headroom. Keeps serving deterministic w.r.t. batch composition.
    fn calibrate(&mut self, rng: &mut Pcg32) {
        let batch = 64;
        let dim = self.cfg.top_input_dim();
        let reqs = self.synth_requests(batch, rng);
        let mut scratch = InferenceScratch::default();
        self.compute_top_input_into(&reqs, &LocalEbStage, &mut scratch);
        let top_in = &scratch.top_in[..batch * dim];
        // Per-column mean/std over the calibration batch.
        let mut mean = vec![0f32; dim];
        for b in 0..batch {
            for (j, m) in mean.iter_mut().enumerate() {
                *m += top_in[b * dim + j];
            }
        }
        for m in &mut mean {
            *m /= batch as f32;
        }
        let mut std = vec![0f32; dim];
        for b in 0..batch {
            for j in 0..dim {
                let d = top_in[b * dim + j] - mean[j];
                std[j] += d * d;
            }
        }
        for s in &mut std {
            *s = (*s / batch as f32).sqrt().max(1e-3);
        }
        self.top_mean = mean;
        self.top_std = std;
        // Standardized features are ~N(0,1); ±4σ with asymmetric headroom
        // keeps the zero code off the modulus (see AbftLinear::random).
        self.top_qparams = QParams::fit_u8(-4.0, 4.4);
    }

    /// Batched forward pass with the default (unsharded) EB stage.
    /// Returns (scores in [0,1], soft-error report).
    pub fn forward(&self, requests: &[DlrmRequest]) -> (Vec<f32>, InferenceReport) {
        self.forward_with(requests, &LocalEbStage)
    }

    /// Batched forward pass with an explicit EB-stage strategy (the shard
    /// router, a test double, …). Scores are bit-identical across
    /// strategies on clean data (see [`EbStage`]).
    ///
    /// Allocating wrapper over [`DlrmModel::forward_into`]; serving paths
    /// hold an [`InferenceScratch`] and call the `_into` form directly.
    pub fn forward_with(
        &self,
        requests: &[DlrmRequest],
        stage: &dyn EbStage,
    ) -> (Vec<f32>, InferenceReport) {
        let mut scratch = InferenceScratch::default();
        let mut scores = vec![0f32; requests.len()];
        let report = self.forward_into(requests, stage, &mut scratch, &mut scores);
        (scores, report)
    }

    /// The zero-allocation forward pass: every intermediate lives in
    /// `scratch` (grow-only — after one warmup batch at the largest
    /// shapes, no heap allocation happens here), scores land in the
    /// caller's buffer. Bit-identical to [`DlrmModel::forward_with`] by
    /// construction (that wrapper delegates here).
    pub fn forward_into(
        &self,
        requests: &[DlrmRequest],
        stage: &dyn EbStage,
        scratch: &mut InferenceScratch,
        scores: &mut [f32],
    ) -> InferenceReport {
        let batch = requests.len();
        assert_eq!(scores.len(), batch, "scores buffer");
        let mut report = self.compute_top_input_into(requests, stage, scratch);
        let top_in_dim = self.cfg.top_input_dim();

        // 5. Standardize per column (calibrated stats), then quantize onto
        // the static lattice and run the top MLP + scalar head.
        let probe = self.obs.probe();
        let t0 = probe.map(|_| Instant::now());
        let mut qp = self.top_qparams;
        let xq = grow(&mut scratch.act_a, batch * top_in_dim);
        for b in 0..batch {
            for j in 0..top_in_dim {
                let z = (scratch.top_in[b * top_in_dim + j] - self.top_mean[j]) / self.top_std[j];
                xq[b * top_in_dim + j] = qp.quantize_u8(z);
            }
        }
        if let (Some(p), Some(t0)) = (probe, t0) {
            p.span(Stage::Requantize, 0, t0);
        }
        let mut width = top_in_dim;
        let nb = self.bottom.len();
        for (j, layer) in self.top.iter().enumerate() {
            grow(&mut scratch.act_b, batch * layer.n);
            let rep = self.gemm_site_forward(
                layer,
                nb + j,
                &scratch.act_a[..batch * width],
                batch,
                qp,
                &mut scratch.gemm,
                &mut scratch.act_b[..batch * layer.n],
            );
            report.gemm.merge(&rep);
            qp = layer.out_qparams;
            width = layer.n;
            std::mem::swap(&mut scratch.act_a, &mut scratch.act_b);
        }
        grow(&mut scratch.act_b, batch);
        let rep = self.gemm_site_forward(
            &self.head,
            nb + self.top.len(),
            &scratch.act_a[..batch * width],
            batch,
            qp,
            &mut scratch.gemm,
            &mut scratch.act_b[..batch],
        );
        report.gemm.merge(&rep);
        for (s, &q) in scores.iter_mut().zip(&scratch.act_b[..batch]) {
            *s = sigmoid(self.head.out_qparams.dequantize_u8(q));
        }
        report
    }

    /// Bottom half of the forward pass: bottom MLP → EBs (via `stage`) →
    /// interaction → concat. Leaves the float top-MLP input in
    /// `scratch.top_in` (batch × top_input_dim).
    fn compute_top_input_into(
        &self,
        requests: &[DlrmRequest],
        stage: &dyn EbStage,
        scratch: &mut InferenceScratch,
    ) -> InferenceReport {
        let batch = requests.len();
        assert!(batch > 0);
        let d = self.cfg.embedding_dim;
        let num_tables = self.tables.len();
        let mut report = InferenceReport::default();

        // 1. Quantize dense inputs against the fixed input lattice.
        let dense_q = grow(&mut scratch.act_a, batch * self.cfg.num_dense);
        for (b, req) in requests.iter().enumerate() {
            assert_eq!(req.dense.len(), self.cfg.num_dense, "dense width");
            assert_eq!(req.sparse.len(), num_tables, "sparse tables");
            for (j, &x) in req.dense.iter().enumerate() {
                dense_q[b * self.cfg.num_dense + j] = self.dense_qparams.quantize_u8(x);
            }
        }

        // 2. Bottom MLP (activations ping-pong between the two scratch
        // buffers; the current input always sits in `act_a`).
        let mut x_qp = self.dense_qparams;
        let mut width = self.cfg.num_dense;
        for (i, layer) in self.bottom.iter().enumerate() {
            grow(&mut scratch.act_b, batch * layer.n);
            let rep = self.gemm_site_forward(
                layer,
                i,
                &scratch.act_a[..batch * width],
                batch,
                x_qp,
                &mut scratch.gemm,
                &mut scratch.act_b[..batch * layer.n],
            );
            report.gemm.merge(&rep);
            x_qp = layer.out_qparams;
            width = layer.n;
            std::mem::swap(&mut scratch.act_a, &mut scratch.act_b);
        }
        let bottom_f = grow(&mut scratch.bottom_f, batch * width);
        for (f, &q) in bottom_f.iter_mut().zip(&scratch.act_a[..batch * width]) {
            *f = x_qp.dequantize_u8(q);
        }

        // 3. EmbeddingBags, ABFT-checked per bag, via the serving
        // strategy: [`LocalEbStage`] reads `self.tables`; the shard
        // router serves replicas — both bit-identical on clean data.
        let groups = num_tables + 1;
        let feats = grow(&mut scratch.feats, batch * groups * d);
        for b in 0..batch {
            feats[b * groups * d..b * groups * d + d]
                .copy_from_slice(&scratch.bottom_f[b * d..(b + 1) * d]);
        }
        let probe = self.obs.probe();
        let t0 = probe.map(|_| Instant::now());
        let eb = stage.run(
            self,
            requests,
            &mut scratch.feats[..batch * groups * d],
            &mut scratch.eb,
        );
        if let (Some(p), Some(t0)) = (probe, t0) {
            p.span(Stage::EbGather, 0, t0);
        }
        report.eb_bags_flagged += eb.flagged;
        report.eb_bags_recomputed += eb.recomputed;
        report.eb_bags_unrecovered += eb.unrecovered;
        report.shard_detections += eb.shard_detections;
        report.shard_failovers += eb.shard_failovers;
        report.shard_quarantines += eb.shard_quarantines;

        // 4. Pairwise interactions + concat with bottom output.
        let pairs = crate::dlrm::interaction::interaction_dim(groups);
        let probe = self.obs.probe();
        let t0 = probe.map(|_| Instant::now());
        pairwise_interaction_into(
            &scratch.feats[..batch * groups * d],
            batch,
            groups,
            d,
            grow(&mut scratch.inter, batch * pairs),
        );
        if let (Some(p), Some(t0)) = (probe, t0) {
            p.span(Stage::Interaction, 0, t0);
        }
        let top_in_dim = d + pairs;
        debug_assert_eq!(top_in_dim, self.cfg.top_input_dim());
        let top_in = grow(&mut scratch.top_in, batch * top_in_dim);
        for b in 0..batch {
            top_in[b * top_in_dim..b * top_in_dim + d]
                .copy_from_slice(&scratch.bottom_f[b * d..(b + 1) * d]);
            top_in[b * top_in_dim + d..(b + 1) * top_in_dim]
                .copy_from_slice(&scratch.inter[b * pairs..(b + 1) * pairs]);
        }
        report
    }

    /// One protected-layer forward under the site's current policy mode,
    /// with telemetry + per-mode served accounting (one relaxed cell
    /// load per layer per batch; a detached [`PolicyHandle`] compiles
    /// down to the plain `forward_into` call).
    fn gemm_site_forward(
        &self,
        layer: &AbftLinear,
        site: usize,
        x: &[u8],
        m: usize,
        x_qparams: QParams,
        gemm: &mut GemmScratch,
        out: &mut [u8],
    ) -> LayerReport {
        let mode = self.policy.gemm_mode(site);
        if let Some(s) = self.policy.sites() {
            s.note_served(mode, m as u64);
        }
        let ctx = SiteCtx::new(
            &self.events,
            SiteId::Gemm(site as u32),
            self.policy.gemm_telem(site),
        )
        .with_obs(&self.obs);
        layer.forward_policied(x, m, x_qparams, mode, ctx, gemm, out)
    }

    /// All tables' bags for one request, written into its `(1+T)·d`
    /// feature row (slot 0 already holds the bottom-MLP output). Each
    /// table is a policy site: its [`crate::policy::DetectionMode`]
    /// decides whether the bag runs the fused checked kernel, an
    /// unchecked gather (`Sampled` skip / `Off`), or the relaxed-bound
    /// check (`BoundOnly`) — all bit-identical in output on clean data.
    fn eb_for_request(
        &self,
        req_ix: usize,
        req: &DlrmRequest,
        fchunk: &mut [f32],
        flags: &mut EbStageReport,
    ) {
        let d = self.cfg.embedding_dim;
        for (t, (table, fused)) in self.tables.iter().zip(&self.fused).enumerate() {
            let indices = &req.sparse[t];
            let out = &mut fchunk[(t + 1) * d..(t + 2) * d];
            if !self.cfg.protection.enabled() {
                bag_sum_8(table, indices, None, true, out);
                continue;
            }
            let (telem, check, bound_scale) = self.policy.eb_bag_policy(t);
            if !check {
                let probe = self.obs.probe();
                let t0 = probe.map(|_| Instant::now());
                bag_sum_8(table, indices, None, true, out);
                if let (Some(p), Some(t0)) = (probe, t0) {
                    p.measured().note_eb_unchecked(t, t0.elapsed().as_nanos() as u64);
                }
                if let Some(tl) = telem {
                    tl.record(1, 0);
                }
                continue;
            }
            let probe = self.obs.probe();
            if let Some(p) = probe {
                // Calibration: time one unchecked gather of the same bag
                // so the checked/unchecked cost ratio is measured under
                // `Full` too (where no bag otherwise runs unchecked).
                // The checked gather below overwrites `out`, so served
                // bytes stay bit-identical.
                let t0 = Instant::now();
                bag_sum_8(table, indices, None, true, out);
                p.measured().note_eb_unchecked(t, t0.elapsed().as_nanos() as u64);
            }
            // Fused gather+reduce+verify: same random-access streams
            // as the unprotected bag (abft::eb §Perf).
            let t0 = probe.map(|_| Instant::now());
            let check0 =
                fused.bag_sum_checked_scaled_ex(table, indices, None, true, bound_scale, out);
            if let (Some(p), Some(t0)) = (probe, t0) {
                let ns = t0.elapsed().as_nanos() as u64;
                p.measured().note_eb_checked(t, ns);
                p.span_ns(Stage::EbBagChecked, t as u32, ns);
            }
            if check0.flagged() {
                flags.flagged += 1;
                // Escalation signal: fed through the site's own handle,
                // independent of sink wiring.
                if let Some(tl) = telem {
                    tl.note_flags(1);
                }
                let resolution = if self.cfg.protection == Protection::DetectRecompute {
                    flags.recomputed += 1;
                    let again = fused
                        .bag_sum_checked_scaled(table, indices, None, true, bound_scale, out);
                    if !again {
                        // Transient: the re-gather verified clean.
                        Resolution::Recovered(Recovery::RecomputeUnit)
                    } else {
                        flags.unrecovered += 1;
                        // Persistent table corruption: locally there is
                        // no replica; the next applicable rung is the
                        // engine's batch retry (which re-reads the same
                        // memory — the batch ends degraded if it also
                        // flags, and the event trail shows the walk).
                        Resolution::escalated_or_degraded(recovery::next_step(
                            SiteClass::EbLocal,
                            Recovery::RecomputeUnit,
                        ))
                    }
                } else {
                    Resolution::DetectedOnly
                };
                self.events.emit(
                    SiteId::Eb(t as u32),
                    UnitRef::Bag { request: req_ix as u32, replica: LOCAL_REPLICA },
                    Detector::EbBound,
                    Severity::from_eb_margin(check0.excess, check0.threshold),
                    resolution,
                );
            }
            if let Some(tl) = telem {
                tl.record(1, 1);
            }
        }
    }

    /// Generate a synthetic request batch (uniform indices, as the paper's
    /// evaluation does; callers can build zipfian traffic via
    /// [`crate::bench::workload`]).
    pub fn synth_requests(&self, batch: usize, rng: &mut Pcg32) -> Vec<DlrmRequest> {
        (0..batch)
            .map(|_| DlrmRequest {
                dense: (0..self.cfg.num_dense).map(|_| rng.next_f32()).collect(),
                sparse: self
                    .cfg
                    .tables
                    .iter()
                    .map(|t| {
                        (0..t.pooling.max(1))
                            .map(|_| rng.gen_range(0, t.rows))
                            .collect()
                    })
                    .collect(),
            })
            .collect()
    }

    /// Total weight bytes (packed MLPs + tables), for sizing reports.
    pub fn weight_bytes(&self) -> usize {
        let mlp: usize = self
            .bottom
            .iter()
            .chain(&self.top)
            .chain(std::iter::once(&self.head))
            .map(|l| l.weight_bytes())
            .sum();
        mlp + self.tables.iter().map(|t| t.bytes()).sum::<usize>()
    }
}

#[inline]
fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dlrm::config::TableConfig;

    fn tiny_cfg(protection: Protection) -> DlrmConfig {
        DlrmConfig {
            num_dense: 4,
            embedding_dim: 8,
            bottom_mlp: vec![16, 8],
            top_mlp: vec![16],
            tables: vec![
                TableConfig { rows: 200, pooling: 5 },
                TableConfig { rows: 100, pooling: 3 },
            ],
            protection,
            dense_range: (0.0, 1.0),
            seed: 7,
        }
    }

    #[test]
    fn forward_produces_probabilities() {
        let model = DlrmModel::random(tiny_cfg(Protection::DetectRecompute));
        let mut rng = Pcg32::new(1);
        let reqs = model.synth_requests(6, &mut rng);
        let (scores, report) = model.forward(&reqs);
        assert_eq!(scores.len(), 6);
        assert!(scores.iter().all(|&s| (0.0..=1.0).contains(&s)));
        assert!(report.clean(), "clean model must not flag: {report:?}");
    }

    #[test]
    fn protection_is_output_transparent() {
        let mut rng = Pcg32::new(2);
        let m_on = DlrmModel::random(tiny_cfg(Protection::DetectRecompute));
        let m_off = DlrmModel::random(tiny_cfg(Protection::Off));
        let reqs = m_on.synth_requests(4, &mut rng);
        let (s_on, _) = m_on.forward(&reqs);
        let (s_off, _) = m_off.forward(&reqs);
        assert_eq!(s_on, s_off, "same seed, same scores regardless of ABFT");
    }

    #[test]
    fn corrupted_mlp_weight_detected_in_forward() {
        let mut model = DlrmModel::random(tiny_cfg(Protection::Detect));
        // Flip a high bit in a packed bottom-layer weight.
        let data = model.bottom[0].abft_mut().packed.data_mut();
        let mid = data.len() / 2;
        data[mid] = (data[mid] as u8 ^ 0x40) as i8;
        let mut rng = Pcg32::new(3);
        let reqs = model.synth_requests(4, &mut rng);
        let (_, report) = model.forward(&reqs);
        assert!(report.gemm.rows_flagged > 0, "{report:?}");
    }

    #[test]
    fn corrupted_table_flagged_and_unrecovered() {
        let mut model = DlrmModel::random(tiny_cfg(Protection::DetectRecompute));
        // Persistent table corruption: high bit of many codes in table 0 —
        // recompute rereads the same bad memory, so it must be reported
        // unrecovered.
        for r in 0..model.tables[0].rows {
            model.tables[0].data[r * model.cfg.embedding_dim] ^= 0x80;
        }
        let mut rng = Pcg32::new(4);
        let reqs = model.synth_requests(4, &mut rng);
        let (_, report) = model.forward(&reqs);
        assert!(report.eb_bags_flagged > 0);
        assert_eq!(report.eb_bags_recomputed, report.eb_bags_flagged);
        assert_eq!(report.eb_bags_unrecovered, report.eb_bags_flagged);
    }

    #[test]
    fn weight_bytes_accounts_tables() {
        let model = DlrmModel::random(tiny_cfg(Protection::Off));
        // tables: 200*8 + 100*8 codes + 300*8 qparam bytes
        assert!(model.weight_bytes() > 200 * 8 + 100 * 8);
    }
}
