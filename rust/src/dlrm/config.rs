//! DLRM model configuration, loadable from JSON (the config system the
//! launcher and examples share).

use crate::util::json::Json;
use anyhow::{anyhow, Result};

/// Per-operator protection switch.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Protection {
    /// No ABFT (baseline).
    Off,
    /// ABFT verification; detections reported but output used as-is.
    Detect,
    /// ABFT verification + recompute of corrupted rows/bags.
    DetectRecompute,
}

impl Protection {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "off" => Ok(Protection::Off),
            "detect" => Ok(Protection::Detect),
            "detect_recompute" => Ok(Protection::DetectRecompute),
            _ => Err(anyhow!("unknown protection mode {s:?}")),
        }
    }

    pub fn enabled(self) -> bool {
        self != Protection::Off
    }
}

/// One embedding table.
#[derive(Clone, Debug, PartialEq)]
pub struct TableConfig {
    pub rows: usize,
    /// Mean lookups per bag for synthetic traffic.
    pub pooling: usize,
}

/// Full model + protection configuration.
#[derive(Clone, Debug)]
pub struct DlrmConfig {
    /// Dense (continuous) input features.
    pub num_dense: usize,
    /// Embedding dimension d (shared across tables, as in DLRM).
    pub embedding_dim: usize,
    /// Bottom-MLP hidden sizes; the last must equal `embedding_dim`.
    pub bottom_mlp: Vec<usize>,
    /// Top-MLP hidden sizes; a final 1-wide output layer is appended.
    pub top_mlp: Vec<usize>,
    pub tables: Vec<TableConfig>,
    pub protection: Protection,
    /// Dense inputs are quantized against this fixed range.
    pub dense_range: (f32, f32),
    pub seed: u64,
}

impl Default for DlrmConfig {
    fn default() -> Self {
        Self {
            num_dense: 13,
            embedding_dim: 64,
            bottom_mlp: vec![512, 256, 64],
            top_mlp: vec![512, 256],
            tables: vec![TableConfig { rows: 100_000, pooling: 30 }; 8],
            protection: Protection::DetectRecompute,
            dense_range: (0.0, 1.0),
            seed: 42,
        }
    }
}

impl DlrmConfig {
    /// Input width of the top MLP: bottom output (d) concatenated with the
    /// pairwise interaction features among (tables + 1) d-vectors.
    pub fn top_input_dim(&self) -> usize {
        let t = self.tables.len() + 1;
        self.embedding_dim + t * (t - 1) / 2
    }

    /// Total trainable parameters (for sizing the e2e run).
    pub fn param_count(&self) -> usize {
        let mut count = 0usize;
        let mut prev = self.num_dense;
        for &h in &self.bottom_mlp {
            count += prev * h;
            prev = h;
        }
        prev = self.top_input_dim();
        for &h in &self.top_mlp {
            count += prev * h;
            prev = h;
        }
        count += prev; // final scalar head
        count += self.tables.iter().map(|t| t.rows * self.embedding_dim).sum::<usize>();
        count
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        let mut cfg = DlrmConfig::default();
        if let Some(v) = j.get("num_dense").and_then(Json::as_usize) {
            cfg.num_dense = v;
        }
        if let Some(v) = j.get("embedding_dim").and_then(Json::as_usize) {
            cfg.embedding_dim = v;
        }
        if let Some(a) = j.get("bottom_mlp").and_then(Json::as_arr) {
            cfg.bottom_mlp = parse_usize_arr(a)?;
        }
        if let Some(a) = j.get("top_mlp").and_then(Json::as_arr) {
            cfg.top_mlp = parse_usize_arr(a)?;
        }
        if let Some(a) = j.get("tables").and_then(Json::as_arr) {
            cfg.tables = a
                .iter()
                .map(|t| {
                    Ok(TableConfig {
                        rows: t
                            .get("rows")
                            .and_then(Json::as_usize)
                            .ok_or_else(|| anyhow!("table needs rows"))?,
                        pooling: t.get("pooling").and_then(Json::as_usize).unwrap_or(30),
                    })
                })
                .collect::<Result<_>>()?;
        }
        if let Some(s) = j.get("protection").and_then(Json::as_str) {
            cfg.protection = Protection::parse(s)?;
        }
        if let Some(v) = j.get("seed").and_then(Json::as_i64) {
            cfg.seed = v as u64;
        }
        if let Some(last) = cfg.bottom_mlp.last() {
            if *last != cfg.embedding_dim {
                return Err(anyhow!(
                    "bottom_mlp must end at embedding_dim ({} != {})",
                    last,
                    cfg.embedding_dim
                ));
            }
        }
        Ok(cfg)
    }

    pub fn from_json_str(s: &str) -> Result<Self> {
        Self::from_json(&Json::parse(s)?)
    }
}

fn parse_usize_arr(a: &[Json]) -> Result<Vec<usize>> {
    a.iter()
        .map(|x| x.as_usize().ok_or_else(|| anyhow!("expected usize")))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_config_sane() {
        let c = DlrmConfig::default();
        assert_eq!(*c.bottom_mlp.last().unwrap(), c.embedding_dim);
        assert_eq!(c.top_input_dim(), 64 + 9 * 8 / 2);
        assert!(c.param_count() > 50_000_000); // embedding dominated
    }

    #[test]
    fn json_roundtrip() {
        let cfg = DlrmConfig::from_json_str(
            r#"{
              "num_dense": 4,
              "embedding_dim": 16,
              "bottom_mlp": [32, 16],
              "top_mlp": [64],
              "tables": [{"rows": 1000}, {"rows": 500, "pooling": 5}],
              "protection": "detect",
              "seed": 7
            }"#,
        )
        .unwrap();
        assert_eq!(cfg.num_dense, 4);
        assert_eq!(cfg.tables.len(), 2);
        assert_eq!(cfg.tables[1].pooling, 5);
        assert_eq!(cfg.protection, Protection::Detect);
    }

    #[test]
    fn rejects_mismatched_bottom() {
        let r = DlrmConfig::from_json_str(
            r#"{"embedding_dim": 16, "bottom_mlp": [32, 8]}"#,
        );
        assert!(r.is_err());
    }

    #[test]
    fn protection_parse() {
        assert_eq!(Protection::parse("off").unwrap(), Protection::Off);
        assert!(Protection::parse("bogus").is_err());
        assert!(!Protection::Off.enabled());
        assert!(Protection::Detect.enabled());
    }
}
