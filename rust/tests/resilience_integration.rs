//! Integration: the full resilience loop — persistent memory corruption,
//! request-path detection, background scrubbing, and repair from the
//! CRC-protected model store.

use dlrm_abft::abft::Scrubber;
use dlrm_abft::coordinator::{Engine, ScoreRequest};
use dlrm_abft::dlrm::{DlrmConfig, DlrmModel, Protection, TableConfig};
use dlrm_abft::util::rng::Pcg32;
use std::sync::atomic::Ordering;

fn cfg() -> DlrmConfig {
    DlrmConfig {
        num_dense: 4,
        embedding_dim: 16,
        bottom_mlp: vec![32, 16],
        top_mlp: vec![32],
        tables: vec![TableConfig { rows: 3_000, pooling: 12 }; 2],
        protection: Protection::DetectRecompute,
        dense_range: (0.0, 1.0),
        seed: 77,
    }
}

fn reqs(model: &DlrmModel, n: usize, seed: u64) -> Vec<ScoreRequest> {
    let mut rng = Pcg32::new(seed);
    model
        .synth_requests(n, &mut rng)
        .into_iter()
        .enumerate()
        .map(|(i, r)| ScoreRequest { id: i as u64, dense: r.dense, sparse: r.sparse })
        .collect()
}

#[test]
fn persistent_corruption_degrades_then_store_repair_recovers() {
    let model = DlrmModel::random(cfg());
    let store = std::env::temp_dir().join("resilience_it_store.dlrm");
    model.save(&store).unwrap();
    let requests = reqs(&model, 8, 1);

    let engine = Engine::new(model);
    let clean: Vec<f32> = engine
        .process_batch(requests.clone())
        .into_iter()
        .map(|r| r.score)
        .collect();
    assert_eq!(engine.metrics.detections.load(Ordering::Relaxed), 0);

    // Persistent corruption: smash the top bit of the first code of EVERY
    // row of table 0 (hardware gone very wrong). Detection must fire, the
    // recompute must re-read the same bad memory, and the response must be
    // marked degraded.
    {
        let mut m = engine.model.write().unwrap();
        let d = m.cfg.embedding_dim;
        for r in 0..m.tables[0].rows {
            m.tables[0].data[r * d] ^= 0x80;
        }
    }
    let resps = engine.process_batch(requests.clone());
    assert!(resps.iter().all(|r| r.detected), "persistent corruption must be detected");
    assert!(resps.iter().all(|r| r.recomputed));
    assert!(resps.iter().all(|r| r.degraded), "recompute cannot fix memory corruption");

    // Repair every corrupted row from the store (what an operator/agent
    // would do on a degraded alert), then verify service recovers.
    {
        let pristine = DlrmModel::load(&store, Protection::DetectRecompute).unwrap();
        let mut m = engine.model.write().unwrap();
        let d = m.cfg.embedding_dim;
        let bad = Scrubber::full_pass(&m.tables[0], &m.checksums[0]);
        assert_eq!(bad.len(), m.tables[0].rows, "scrubber must see every smashed row");
        for row in bad {
            let src = &pristine.tables[0].data[row * d..(row + 1) * d];
            m.tables[0].data[row * d..(row + 1) * d].copy_from_slice(src);
        }
        assert!(Scrubber::full_pass(&m.tables[0], &m.checksums[0]).is_empty());
    }
    let healed: Vec<f32> = engine
        .process_batch(requests)
        .into_iter()
        .map(|r| {
            assert!(!r.detected);
            r.score
        })
        .collect();
    assert_eq!(healed, clean, "post-repair scores must match pre-corruption");
    std::fs::remove_file(&store).ok();
}

#[test]
fn scrub_tick_finds_cold_corruption_the_request_path_misses() {
    let model = DlrmModel::random(cfg());
    let engine = Engine::new(model).with_scrubbing(1000);

    // Corrupt one cold row (never referenced by our requests: we'll only
    // look up rows < 100, corrupt row 2999).
    {
        let mut m = engine.model.write().unwrap();
        let d = m.cfg.embedding_dim;
        m.tables[1].data[2999 * d + 3] ^= 0x40;
    }
    // Requests that never touch the corrupted row: no request-path detection.
    let mut rng = Pcg32::new(9);
    let reqs: Vec<ScoreRequest> = (0..4)
        .map(|i| ScoreRequest {
            id: i,
            dense: (0..4).map(|_| rng.next_f32()).collect(),
            sparse: vec![
                (0..12).map(|_| rng.gen_range(0, 100)).collect(),
                (0..12).map(|_| rng.gen_range(0, 100)).collect(),
            ],
        })
        .collect();
    let resps = engine.process_batch(reqs);
    assert!(resps.iter().all(|r| !r.detected), "cold corruption is invisible to requests");

    // The scrubber, ticking through strips, finds it within one full pass.
    let mut hits = Vec::new();
    for _ in 0..3 {
        // 3000 rows / 1000 stride
        let tick = engine.scrub_tick();
        assert_eq!(tick.rows_scanned, 2 * 1000, "both tables advance one strip");
        hits.extend(tick.hits);
    }
    assert_eq!(hits, vec![(1, 2999)]);
    assert_eq!(engine.metrics.scrub_hits.load(Ordering::Relaxed), 1);
    assert_eq!(engine.metrics.scrubbed_rows.load(Ordering::Relaxed), 2 * 3000);
}

#[test]
fn snapshot_roundtrip_through_engine() {
    let model = DlrmModel::random(cfg());
    let store = std::env::temp_dir().join("resilience_it_engine.dlrm");
    model.save(&store).unwrap();
    let requests = reqs(&model, 5, 3);
    let e1 = Engine::new(model);
    let s1: Vec<f32> = e1.process_batch(requests.clone()).into_iter().map(|r| r.score).collect();
    let e2 = Engine::new(DlrmModel::load(&store, Protection::DetectRecompute).unwrap());
    let s2: Vec<f32> = e2.process_batch(requests).into_iter().map(|r| r.score).collect();
    assert_eq!(s1, s2);
    std::fs::remove_file(&store).ok();
}
