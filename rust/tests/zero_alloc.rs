//! Steady-state allocation regression test (PR 3 acceptance criterion):
//! after warmup, `Engine::score` must perform **zero** heap allocations
//! on the clean serving path — unsharded and sharded.
//!
//! A counting global allocator tallies every `alloc`/`realloc`. The
//! invariant covers the kernel fan-out path too (PR 8): the thread pool
//! type-erases jobs into fixed slots on a pre-allocated ring and tracks
//! scope joins on the scope's stack frame, so a batch large enough to
//! cross the GEMM/EB parallelism gates still scores with zero steady-
//! state allocations. Small-batch phases prove the inline path, the
//! `fanout` phase proves the parallel one. This file holds exactly one
//! `#[test]` so no concurrent test case can pollute the counter.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static A: CountingAlloc = CountingAlloc;

use dlrm_abft::coordinator::{Engine, ScoreRequest};
use dlrm_abft::dlrm::{DlrmConfig, DlrmModel, Protection, TableConfig};
use dlrm_abft::shard::ShardPlan;
use dlrm_abft::util::rng::Pcg32;

fn tiny_model(seed: u64) -> DlrmModel {
    DlrmModel::random(DlrmConfig {
        num_dense: 8,
        embedding_dim: 16,
        bottom_mlp: vec![32, 16],
        top_mlp: vec![32],
        tables: vec![
            TableConfig { rows: 400, pooling: 6 },
            TableConfig { rows: 300, pooling: 4 },
        ],
        protection: Protection::DetectRecompute,
        dense_range: (0.0, 1.0),
        seed,
    })
}

/// A model + batch shape that crosses BOTH kernel fan-out gates, so the
/// scored pass exercises pool submission, the slot ring, and stack-frame
/// scope joins: bottom layer 0 is m·k·n_total = 128·64·(256+extras)
/// ≥ `GEMM_PAR_MIN_WORK` (2^21) MACs, and the EB stage sums
/// Σ pooling·d·batch = 70·16·128 = 143,360 ≥ `EB_PAR_MIN_WORK` (2^17).
fn fanout_model(seed: u64) -> DlrmModel {
    DlrmModel::random(DlrmConfig {
        num_dense: 64,
        embedding_dim: 16,
        bottom_mlp: vec![256, 16],
        top_mlp: vec![32],
        tables: vec![
            TableConfig { rows: 400, pooling: 40 },
            TableConfig { rows: 300, pooling: 30 },
        ],
        protection: Protection::DetectRecompute,
        dense_range: (0.0, 1.0),
        seed,
    })
}

fn steady_state_allocs(engine: &Engine, batch: usize, label: &str) {
    let mut rng = Pcg32::new(0x5EED);
    let model = engine.model.read().unwrap();
    let reqs = model.synth_requests(batch, &mut rng);
    drop(model);
    let mut scores = vec![0f32; batch];

    // Warmup: grows every scratch buffer to its high-water mark and
    // parks one arena in the engine pool.
    for _ in 0..3 {
        let outcome = engine.score(&reqs, &mut scores);
        assert!(!outcome.detected, "{label}: clean model must not detect");
    }

    let before = ALLOCS.load(Ordering::Relaxed);
    for _ in 0..20 {
        engine.score(&reqs, &mut scores);
    }
    let after = ALLOCS.load(Ordering::Relaxed);
    assert_eq!(
        after - before,
        0,
        "{label}: Engine::score allocated in steady state"
    );
    assert!(scores.iter().all(|s| (0.0..=1.0).contains(s)));
}

/// The socket-boundary half of the invariant: after one warmup parse at
/// the steady request shape, [`ScoreRequest::parse_line_into`] reuses the
/// slabbed `dense`/`sparse` buffers and performs zero allocations.
fn steady_state_parse_allocs() {
    let lines: Vec<String> = (0..4)
        .map(|i| {
            format!(
                r#"{{"id":{i},"dense":[0.25,1.5,{i}.0,2.75],"sparse":[[1,2,3,4,{i}],[6,7,8]]}}"#
            )
        })
        .collect();
    let mut req = ScoreRequest::default();
    // Warmup: grows dense + both inner sparse Vecs to the shape's
    // high-water mark.
    for line in &lines {
        assert!(req.parse_line_into(line), "fast path must accept {line}");
    }
    let before = ALLOCS.load(Ordering::Relaxed);
    for _ in 0..20 {
        for line in &lines {
            assert!(req.parse_line_into(line));
        }
    }
    let after = ALLOCS.load(Ordering::Relaxed);
    assert_eq!(after - before, 0, "parse path allocated in steady state");
    assert_eq!(req.id, 3);
    assert_eq!(req.sparse.len(), 2);
}

#[test]
fn engine_score_steady_state_is_allocation_free() {
    // Unsharded: local EB stage, fused MLP pipeline, pooled arena. The
    // engine always carries an attached fault-event sink (PR 5), so this
    // also proves the journal holds the zero-alloc contract: it is
    // pre-sized at attach and the clean path never emits.
    let engine = Engine::new(tiny_model(0x21));
    steady_state_allocs(&engine, 4, "unsharded");
    assert_eq!(engine.journal().total(), 0, "clean traffic journals nothing");

    // Sharded: the router's per-shard fan-out buffers pool in the arena's
    // EbScratch — the "router scratch allocates per batch" ROADMAP item.
    let sharded = Engine::new(tiny_model(0x21)).with_shards(ShardPlan::hash_placement(2, 2, 2), 64);
    steady_state_allocs(&sharded, 4, "sharded");

    // Profiled: full-rate span sampling (1-in-1) exercises every probe,
    // ring write, stage histogram record, and measured-cost EWMA update
    // on the hot path — the span profiler records into pre-sized rings
    // and packed atomics, so profiling must not break the invariant.
    let profiled = Engine::new(tiny_model(0x21));
    profiled.obs().set_sampling(1);
    steady_state_allocs(&profiled, 4, "profiled");

    // Fan-out: a batch crossing the GEMM and EB parallelism gates runs
    // row blocks and request chunks on the global pool. The fixed-slot
    // job ring + stack-frame scope state (PR 8) make pool submission
    // allocation-free, so the invariant now holds through parallel
    // scoring too — this was the "workers box one closure per job"
    // carve-out in earlier revisions of this test.
    let fanout = Engine::new(fanout_model(0x21));
    steady_state_allocs(&fanout, 128, "fanout");

    // Armed flight recorder, clean traffic: arming preallocates the
    // capture pool up front (PR 9); with no faults the freeze path is
    // never consulted — probes stay one relaxed load and the scored
    // batch's flow guard is two thread-local stores — so the recorder
    // must not break the invariant. Sampling stays on to prove the
    // armed + profiled combination.
    let armed = Engine::new(tiny_model(0x21));
    armed.obs().set_sampling(1);
    let rec = armed.arm_flightrec(4, dlrm_abft::detect::Severity::Significant);
    steady_state_allocs(&armed, 4, "armed recorder");
    assert_eq!(rec.captures_taken(), 0, "clean traffic must not freeze captures");

    // Request parsing: the zero-alloc boundary extends to the socket.
    steady_state_parse_allocs();
}
