//! Property-based tests over the ABFT invariants, using a from-scratch
//! mini-framework (proptest is not in the offline crate set): random cases
//! from a seeded PCG stream; on failure the failing case parameters are in
//! the panic message for direct reproduction.

use dlrm_abft::abft::{encode_checksum_col, AbftGemm, EbChecksum, RowCorrection};
use dlrm_abft::dlrm::{AbftLinear, DlrmConfig, DlrmModel, Protection, TableConfig};
use dlrm_abft::embedding::{bag_sum_8, QuantTable8};
use dlrm_abft::gemm::{gemm_naive, PackedB};
use dlrm_abft::detect::SiteCtx;
use dlrm_abft::policy::{DetectionMode, PolicyHandle, PolicySites, SiteTelemetry};
use dlrm_abft::quant::{get_nibble, pack_nibbles, QParams};
use dlrm_abft::util::rng::Pcg32;
use dlrm_abft::util::scratch::GemmScratch;
use std::sync::Arc;

const CASES: usize = 60;

/// Run `f` on `CASES` seeded random cases; panic messages carry the case id.
fn forall(name: &str, mut f: impl FnMut(&mut Pcg32, usize)) {
    for case in 0..CASES {
        let mut rng = Pcg32::new(0x9E3779B9 ^ (case as u64) << 8 ^ name.len() as u64);
        f(&mut rng, case);
    }
}

fn rand_shape(rng: &mut Pcg32) -> (usize, usize, usize) {
    (rng.gen_range(1, 12), rng.gen_range(1, 96), rng.gen_range(1, 64))
}

fn rand_ab(rng: &mut Pcg32, m: usize, k: usize, n: usize) -> (Vec<u8>, Vec<i8>) {
    let mut a = vec![0u8; m * k];
    let mut b = vec![0i8; k * n];
    rng.fill_u8(&mut a);
    rng.fill_i8(&mut b);
    (a, b)
}

#[test]
fn prop_packed_gemm_equals_naive() {
    forall("packed=naive", |rng, case| {
        let (m, k, n) = rand_shape(rng);
        let (a, b) = rand_ab(rng, m, k, n);
        let packed = PackedB::pack(&b, k, n);
        assert_eq!(
            dlrm_abft::gemm::gemm_exec(&a, &packed, m),
            gemm_naive(&a, &b, m, k, n),
            "case {case}: shape ({m},{k},{n})"
        );
    });
}

#[test]
fn prop_clean_abft_never_false_positives() {
    // Integer arithmetic has no round-off: clean runs must NEVER flag,
    // for any shape and any odd modulus (§VI-B1's zero-FP claim).
    forall("no-fp", |rng, case| {
        let (m, k, n) = rand_shape(rng);
        let (a, b) = rand_ab(rng, m, k, n);
        let modulus = [127, 125, 63, 31, 3][rng.gen_range(0, 5)];
        let abft = AbftGemm::with_modulus(&b, k, n, modulus);
        let (_, verdict) = abft.exec(&a, m);
        assert!(verdict.clean(), "case {case}: shape ({m},{k},{n}) mod {modulus}");
    });
}

#[test]
fn prop_any_nondivisible_delta_is_detected() {
    // Inject an arbitrary delta into one payload element of C_temp: the
    // row is flagged iff delta % modulus != 0 — exactly the paper's
    // §IV-C detectability condition, both directions.
    forall("delta-detect", |rng, case| {
        let (m, k, n) = rand_shape(rng);
        let (a, b) = rand_ab(rng, m, k, n);
        let abft = AbftGemm::new(&b, k, n);
        let nt = abft.n_total();
        let (mut c, _) = abft.exec(&a, m);
        let row = rng.gen_range(0, m);
        let col = rng.gen_range(0, n);
        let delta = rng.next_u32() as i32 % 100_000;
        if delta == 0 {
            return;
        }
        c[row * nt + col] = c[row * nt + col].wrapping_add(delta);
        let verdict = abft.verify(&c, m);
        if delta % 127 == 0 {
            assert!(verdict.clean(), "case {case}: delta {delta} divisible by 127 must escape");
        } else {
            assert_eq!(
                verdict.corrupted_rows,
                vec![row],
                "case {case}: delta {delta} at ({row},{col}) shape ({m},{k},{n})"
            );
        }
    });
}

#[test]
fn prop_checksum_col_congruent_to_rowsum() {
    forall("congruence", |rng, case| {
        let k = rng.gen_range(1, 64);
        let n = rng.gen_range(1, 128);
        let mut b = vec![0i8; k * n];
        rng.fill_i8(&mut b);
        let col = encode_checksum_col(&b, k, n, 127);
        for p in 0..k {
            let s: i32 = b[p * n..(p + 1) * n].iter().map(|&v| v as i32).sum();
            assert_eq!(
                (s - col[p] as i32) % 127,
                0,
                "case {case}: row {p} checksum not congruent"
            );
        }
    });
}

#[test]
fn prop_recompute_row_restores_exact_values() {
    forall("recompute", |rng, case| {
        let (m, k, n) = rand_shape(rng);
        let (a, b) = rand_ab(rng, m, k, n);
        let abft = AbftGemm::new(&b, k, n);
        let nt = abft.n_total();
        let (mut c, _) = abft.exec(&a, m);
        let clean = c.clone();
        // Corrupt up to 3 elements of one row — payload, Eq-3b checksum,
        // or group checksum columns; the recompute restores them all.
        let row = rng.gen_range(0, m);
        for _ in 0..rng.gen_range(1, 4) {
            let col = rng.gen_range(0, nt);
            c[row * nt + col] ^= 1 << rng.gen_range_u32(31);
        }
        abft.recompute_row(&a, row, &mut c, m);
        assert_eq!(c, clean, "case {case}");
    });
}

#[test]
fn prop_quantize_dequantize_bounded_error() {
    forall("quant-bound", |rng, case| {
        let lo = rng.next_f32() * -10.0;
        let hi = rng.next_f32() * 10.0 + lo + 0.1;
        let qp = QParams::fit_u8(lo, hi);
        for _ in 0..50 {
            let x = lo + (hi - lo) * rng.next_f32();
            let err = (qp.dequantize_u8(qp.quantize_u8(x)) - x).abs();
            assert!(
                err <= qp.alpha * 0.5 + 1e-5,
                "case {case}: x={x} err={err} alpha={}",
                qp.alpha
            );
        }
    });
}

#[test]
fn prop_nibble_pack_roundtrip() {
    forall("nibble", |rng, case| {
        let len = rng.gen_range(0, 200);
        let codes: Vec<u8> = (0..len).map(|_| rng.next_u8() & 0x0f).collect();
        let packed = pack_nibbles(&codes);
        for (i, &c) in codes.iter().enumerate() {
            assert_eq!(get_nibble(&packed, i), c, "case {case}: idx {i}");
        }
    });
}

#[test]
fn prop_eb_checksum_flags_iff_delta_above_bound() {
    // Perturb one output element by a known delta and check the Eq-5
    // decision agrees with the bound arithmetic in both directions.
    forall("eb-bound", |rng, case| {
        let rows = rng.gen_range(50, 500);
        let d = [16, 32, 64][rng.gen_range(0, 3)];
        let table = QuantTable8::random(rows, d, rng);
        let cs = EbChecksum::build_8(&table);
        let m = rng.gen_range(5, 60);
        let indices: Vec<usize> = (0..m).map(|_| rng.gen_range(0, rows)).collect();
        let mut r = vec![0f32; d];
        bag_sum_8(&table, &indices, None, false, &mut r);
        assert!(
            !cs.check_bag(&table.alpha, &table.beta, &indices, None, &r),
            "case {case}: clean bag flagged"
        );
        // A delta 100× the bound must flag.
        let rsum: f64 = r.iter().map(|&x| x as f64).sum();
        let big = (rsum.abs().max(1.0) * 1e-3) as f32;
        r[0] += big;
        assert!(
            cs.check_bag(&table.alpha, &table.beta, &indices, None, &r),
            "case {case}: delta {big} not flagged (rsum={rsum})"
        );
    });
}

#[test]
fn prop_eb_weighted_linearity() {
    // Eq 5 with weights: scaling all weights by c scales both sides by c.
    forall("eb-linear", |rng, case| {
        let rows = 200;
        let d = 24;
        let table = QuantTable8::random(rows, d, rng);
        let cs = EbChecksum::build_8(&table);
        let m = rng.gen_range(3, 30);
        let indices: Vec<usize> = (0..m).map(|_| rng.gen_range(0, rows)).collect();
        let w1: Vec<f32> = (0..m).map(|_| rng.next_f32() + 0.1).collect();
        let c = 2.5f32;
        let w2: Vec<f32> = w1.iter().map(|&w| w * c).collect();
        let s1 = cs.expected_sum(&table.alpha, &table.beta, &indices, Some(&w1));
        let s2 = cs.expected_sum(&table.alpha, &table.beta, &indices, Some(&w2));
        assert!(
            (s2 - s1 * c as f64).abs() <= 1e-6 * s2.abs().max(1.0),
            "case {case}: {s2} != {c} * {s1}"
        );
    });
}

#[test]
fn prop_eb_cancellation_class_needs_the_dual_checksum() {
    // §IV-C cancellation class, store-side: corrupt two slots of one row
    // by +t and −t. The plain sum checksum (C_T) is blind to the entire
    // class; the index-weighted sum (C_W) moves by t·(j1−j2) ≠ 0, so the
    // dual check flags the row — and the localizer correctly refuses to
    // name a slot (S = 0 admits no single-slot explanation).
    forall("eb-cancel", |rng, case| {
        let rows = rng.gen_range(20, 200);
        let d = [8, 16, 32, 64][rng.gen_range(0, 4)];
        let mut table = QuantTable8::random(rows, d, rng);
        let row = rng.gen_range(0, rows);
        let j1 = rng.gen_range(0, d);
        let mut j2 = rng.gen_range(0, d);
        while j2 == j1 {
            j2 = rng.gen_range(0, d);
        }
        // Pin the victims to mid-range BEFORE building the checksums so
        // the ±t pair below can never overflow a u8 code.
        let (i1, i2) = (row * d + j1, row * d + j2);
        table.data[i1] = 100;
        table.data[i2] = 100;
        let cs = EbChecksum::build_8(&table);
        let t = rng.gen_range(1, 100) as u8;
        table.data[i1] += t;
        table.data[i2] -= t;
        assert_eq!(
            cs.row_delta(&table, row),
            0,
            "case {case}: the plain checksum must be blind to cancellation"
        );
        let w = cs.weighted_row_delta(&table, row);
        assert_eq!(
            w,
            t as i64 * (j1 as i64 - j2 as i64),
            "case {case}: weighted residual is the closed form"
        );
        assert_ne!(w, 0, "case {case}: the dual checksum must flag");
        assert!(!cs.row_clean(&table, row), "case {case}");
        assert_eq!(
            cs.localize_slot(&table, row),
            None,
            "case {case}: no single-slot rewrite explains S = 0"
        );
        // Undo one side: a lone corrupt slot IS localized exactly.
        table.data[i2] += t;
        assert_eq!(
            cs.localize_slot(&table, row),
            Some((j1, 100)),
            "case {case}: single-slot corruption must be named"
        );
    });
}

#[test]
fn prop_single_gemm_fault_corrected_bit_exactly() {
    // PR-6 correction property: ANY single detectable delta — any
    // magnitude, any payload column or the Eq-3b checksum column itself,
    // any shape — is localized by the group partial checksums and fixed
    // to the bit-exact clean accumulator.
    forall("gemm-correct", |rng, case| {
        let (m, k, n) = rand_shape(rng);
        let (a, b) = rand_ab(rng, m, k, n);
        let abft = AbftGemm::new(&b, k, n);
        let nt = abft.n_total();
        let (mut c, _) = abft.exec(&a, m);
        let clean = c.clone();
        let row = rng.gen_range(0, m);
        let col = rng.gen_range(0, n + 1);
        let delta = rng.next_u32() as i32 % 100_000;
        if delta == 0 || delta % 127 == 0 {
            return; // undetectable by construction (§IV-C)
        }
        c[row * nt + col] = c[row * nt + col].wrapping_add(delta);
        assert_eq!(abft.verify(&c, m).corrupted_rows, vec![row], "case {case}");
        match abft.correct_row(&a, row, &mut c, m) {
            RowCorrection::Corrected { col: got, delta: d } => {
                assert_eq!(got, col, "case {case}: wrong column named");
                assert_eq!(d, delta as i64, "case {case}: wrong delta");
            }
            RowCorrection::Declined(why) => {
                panic!("case {case}: declined ({why:?}) shape ({m},{k},{n}) col {col}")
            }
        }
        assert_eq!(c, clean, "case {case}: correction must be bit-exact");
    });
}

#[test]
fn prop_sampled_rate_one_is_identical_to_full_verify() {
    // The policy invariant: Sampled(1) checks every row with the same
    // verdict as Full, for any corruption pattern and any phase.
    forall("sampled1=full", |rng, case| {
        let (m, k, n) = rand_shape(rng);
        let (a, b) = rand_ab(rng, m, k, n);
        let abft = AbftGemm::new(&b, k, n);
        let (mut c, _) = abft.exec(&a, m);
        for _ in 0..rng.gen_range(0, 5) {
            let i = rng.gen_range(0, m * abft.n_total());
            c[i] ^= 1 << rng.gen_range_u32(31);
        }
        let full = abft.verify(&c, m);
        for phase in [0u64, 1, 7, rng.next_u32() as u64] {
            let sampled = abft.verify_sampled(&c, m, 1, phase);
            assert_eq!(sampled, full, "case {case}: phase {phase} shape ({m},{k},{n})");
        }
        assert_eq!(AbftGemm::sampled_rows(m, 1, 3), m, "case {case}");
    });
}

#[test]
fn prop_sampled_one_layer_forward_bit_identical_to_full() {
    // Layer level, every dispatch path (scalar/SIMD/parallel all route
    // through forward_policied): Sampled(1) output bytes and report
    // equal Full's, clean and corrupted.
    forall("layer-sampled1", |rng, case| {
        let m = rng.gen_range(1, 9);
        let k = rng.gen_range(8, 64);
        let n = rng.gen_range(8, 48);
        let mut layer = AbftLinear::random(k, n, true, Protection::DetectRecompute, rng);
        if case % 2 == 1 {
            // Corrupt a packed payload byte so detection fires.
            let idx = layer.abft().packed.offset(rng.gen_range(0, k), rng.gen_range(0, n));
            let data = layer.abft_mut().packed.data_mut();
            data[idx] = (data[idx] as u8 ^ 0x40) as i8;
        }
        let xf: Vec<f32> = (0..m * k).map(|_| rng.next_f32()).collect();
        let (x, xp) = dlrm_abft::quant::quantize_slice_u8(&xf);
        let mut scratch = GemmScratch::default();
        let mut out_full = vec![0u8; m * n];
        let rep_full = layer.forward_into(&x, m, xp, &mut scratch, &mut out_full);
        let telem = SiteTelemetry::default();
        let mut out_s1 = vec![0u8; m * n];
        let rep_s1 = layer.forward_policied(
            &x,
            m,
            xp,
            DetectionMode::Sampled(1),
            SiteCtx::bare(Some(&telem)),
            &mut scratch,
            &mut out_s1,
        );
        assert_eq!(out_s1, out_full, "case {case}: Sampled(1) must be bit-identical");
        assert_eq!(rep_s1, rep_full, "case {case}: identical reports");
    });
}

#[test]
fn prop_model_forward_bit_identical_across_modes_on_clean_data() {
    // Whole-model invariant: on clean data, scores do not depend on the
    // detection mode — Sampled(1)==Full==detached, and even Off/BoundOnly
    // only change coverage, never values.
    forall("model-modes", |rng, case| {
        if case >= 8 {
            return; // model builds are expensive; 8 seeds suffice
        }
        let cfg = DlrmConfig {
            num_dense: 4,
            embedding_dim: 8,
            bottom_mlp: vec![12, 8],
            top_mlp: vec![12],
            tables: vec![
                TableConfig { rows: 60, pooling: 4 },
                TableConfig { rows: 40, pooling: 3 },
            ],
            protection: Protection::DetectRecompute,
            dense_range: (0.0, 1.0),
            seed: 0x517E ^ case as u64,
        };
        let mut model = DlrmModel::random(cfg);
        let reqs = model.synth_requests(4, rng);
        let (want, rep) = model.forward(&reqs);
        assert!(rep.clean());
        let gemm_sites = model.bottom.len() + model.top.len() + 1;
        let sites = Arc::new(PolicySites::new(gemm_sites, model.tables.len(), 1e3, 64));
        model.policy = PolicyHandle::attached(Arc::clone(&sites));
        for mode in [
            DetectionMode::Sampled(1),
            DetectionMode::Sampled(3),
            DetectionMode::BoundOnly,
            DetectionMode::Off,
        ] {
            sites.set_all(mode);
            let (got, rep) = model.forward(&reqs);
            assert_eq!(got, want, "case {case}: mode {mode:?} moved clean scores");
            assert!(rep.clean(), "case {case}: clean data flagged under {mode:?}");
        }
        // Sampled(1) verified every unit: telemetry agrees.
        let eb0 = &sites.eb[0].telem;
        assert!(eb0.units.load(std::sync::atomic::Ordering::Relaxed) > 0, "case {case}");
    });
}

#[test]
fn prop_verdict_rows_sorted_and_unique() {
    forall("verdict-shape", |rng, case| {
        let (m, k, n) = rand_shape(rng);
        let (a, b) = rand_ab(rng, m, k, n);
        let abft = AbftGemm::new(&b, k, n);
        let (mut c, _) = abft.exec(&a, m);
        for _ in 0..rng.gen_range(1, 6) {
            let i = rng.gen_range(0, m * abft.n_total());
            c[i] ^= 1 << rng.gen_range_u32(31);
        }
        let v = abft.verify(&c, m);
        let mut sorted = v.corrupted_rows.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(v.corrupted_rows, sorted, "case {case}");
        assert!(v.corrupted_rows.iter().all(|&r| r < m), "case {case}");
    });
}
