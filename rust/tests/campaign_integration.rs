//! Integration: scaled-down Table II / Table III campaigns land in the
//! statistical bands the paper reports.

use dlrm_abft::bench::figures::{run_table2, run_table3};
use dlrm_abft::fault::campaign::{EbCampaignConfig, GemmCampaignConfig};
use dlrm_abft::util::stats::wilson_interval;

#[test]
fn table2_bands() {
    let cfg = GemmCampaignConfig {
        // Keep the shapes small in the debug profile; the m mix matters
        // (detection improves with m) so keep the paper's m values.
        shapes: vec![(1, 128, 64), (50, 128, 64), (100, 64, 64), (150, 64, 32)],
        runs_per_shape: 30,
        ..Default::default()
    };
    let mut sink = Vec::new();
    let r = run_table2(&cfg, 1, &mut sink);
    // error-in-C: certain detection; no-error: zero FPs (integer exactness).
    assert_eq!(r.error_in_c.not_detected, 0);
    assert_eq!(r.no_error.detected, 0);
    // error-in-B: paper 95.11%; analytic floor at m=1 is 96.9%+ mixing to
    // ~100% for bigger m. Accept a generous Wilson band around 95%.
    let (lo, _) = wilson_interval(r.error_in_b.detected, r.error_in_b.total(), 2.58);
    assert!(lo > 0.85, "B-detection too low: {:?}", r.error_in_b);
}

#[test]
fn table3_bands() {
    let cfg = EbCampaignConfig {
        table_rows: 50_000,
        dim: 64,
        ..Default::default()
    };
    let mut sink = Vec::new();
    let r = run_table3(&cfg, 4, &mut sink); // 50/50/100 runs
    // High-significance flips: paper 99.5%.
    assert!(r.high_bits.rate() > 0.85, "{:?}", r.high_bits);
    // Low-significance flips sit near the bound: partial detection (47%).
    assert!(r.low_bits.rate() < 1.0, "{:?}", r.low_bits);
    // False positives: paper 9.5% — must stay well below half.
    assert!(r.no_error.rate() < 0.35, "{:?}", r.no_error);
}

#[test]
fn table2_deterministic_given_seed() {
    let cfg = GemmCampaignConfig {
        shapes: vec![(4, 64, 32)],
        runs_per_shape: 20,
        ..Default::default()
    };
    let mut s1 = Vec::new();
    let mut s2 = Vec::new();
    let r1 = run_table2(&cfg, 1, &mut s1);
    let r2 = run_table2(&cfg, 1, &mut s2);
    assert_eq!(r1.error_in_b.detected, r2.error_in_b.detected);
    assert_eq!(String::from_utf8(s1).unwrap(), String::from_utf8(s2).unwrap());
}
