//! Integration: the observability plane — span profiler wired through
//! the serving pipeline, live measured-overhead accounting feeding the
//! policy block, and the `trace` / `prom` / cursored-`events` server
//! ops.
//!
//! The profiler's own mechanics (packing, 1-in-n exactness, ring wrap)
//! are unit-tested in `obs::profiler`; this file checks the wiring:
//! spans really cover the scoring pipeline, measured overheads really
//! reach the controller's budget math, and the exposition ops really
//! round-trip over TCP.

use dlrm_abft::coordinator::{BatchPolicy, Client, Engine, ScoreRequest, Server};
use dlrm_abft::dlrm::{DlrmConfig, DlrmModel, DlrmRequest, Protection, TableConfig};
use dlrm_abft::policy::PolicyConfig;
use dlrm_abft::shard::ShardPlan;
use dlrm_abft::util::json::Json;
use dlrm_abft::util::rng::Pcg32;
use std::collections::HashMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn model(seed: u64) -> DlrmModel {
    DlrmModel::random(DlrmConfig {
        num_dense: 8,
        embedding_dim: 16,
        bottom_mlp: vec![32, 16],
        top_mlp: vec![32],
        tables: vec![
            TableConfig { rows: 2_000, pooling: 8 },
            TableConfig { rows: 1_000, pooling: 5 },
        ],
        protection: Protection::DetectRecompute,
        dense_range: (0.0, 1.0),
        seed,
    })
}

/// Engine-level batches (`Engine::score` takes `DlrmRequest`s).
fn requests(model: &DlrmModel, n: usize, seed: u64) -> Vec<DlrmRequest> {
    let mut rng = Pcg32::new(seed);
    model.synth_requests(n, &mut rng)
}

/// Wire-level requests for the TCP round-trip test.
fn score_requests(model: &DlrmModel, n: usize, seed: u64) -> Vec<ScoreRequest> {
    requests(model, n, seed)
        .into_iter()
        .enumerate()
        .map(|(i, r)| ScoreRequest { id: i as u64, dense: r.dense, sparse: r.sparse })
        .collect()
}

/// Per-stage `total_us` from the engine's stage-histogram block.
fn stage_totals(engine: &Engine) -> HashMap<String, f64> {
    let doc = engine.obs().stages_json();
    let mut out = HashMap::new();
    if let Some(stages) = doc.get("stages").and_then(Json::as_arr) {
        for s in stages {
            let name = s.get("stage").and_then(Json::as_str).unwrap().to_string();
            let total = s.get("total_us").and_then(Json::as_f64).unwrap();
            out.insert(name, total);
        }
    }
    out
}

/// The steady-state pipeline stages, all timed on the scoring thread
/// over disjoint intervals — their span totals must bracket the wall
/// time of a scoring loop. (`eb_bag_checked` nests inside `eb_gather`
/// and would double-count; the rare recovery rungs never fire here.)
const PIPELINE_STAGES: [&str; 5] =
    ["eb_gather", "interaction", "mlp_layer", "verify", "requantize"];

#[test]
fn pipeline_spans_account_for_scoring_wall_time() {
    let m = model(0x71);
    let reqs = requests(&m, 8, 1);
    let engine = Engine::new(m);
    engine.obs().set_sampling(1);
    let mut scores = vec![0f32; reqs.len()];
    for _ in 0..2 {
        let outcome = engine.score(&reqs, &mut scores);
        assert!(!outcome.detected, "clean model must not detect");
    }

    let before = stage_totals(&engine);
    let t0 = Instant::now();
    for _ in 0..12 {
        engine.score(&reqs, &mut scores);
    }
    let wall_us = t0.elapsed().as_nanos() as f64 / 1e3;
    let after = stage_totals(&engine);

    let mut sum_us = 0.0;
    for stage in PIPELINE_STAGES {
        let d = after.get(stage).copied().unwrap_or(0.0)
            - before.get(stage).copied().unwrap_or(0.0);
        assert!(d > 0.0, "stage {stage} recorded nothing under 1-in-1 sampling");
        sum_us += d;
    }
    // Disjoint sub-intervals of the loop can't exceed its wall time
    // (small slack for histogram rounding), and the five stages are the
    // bulk of `score` — a loose floor catches spans measuring the wrong
    // thing without making the test timing-sensitive.
    assert!(
        sum_us <= wall_us * 1.10,
        "stage spans ({sum_us:.0}µs) exceed the scoring wall time ({wall_us:.0}µs)"
    );
    assert!(
        sum_us >= wall_us * 0.15,
        "stage spans ({sum_us:.0}µs) cover almost none of the scoring wall time ({wall_us:.0}µs)"
    );
}

#[test]
fn sampling_off_by_default_records_nothing() {
    let m = model(0x72);
    let reqs = requests(&m, 4, 2);
    let engine = Engine::new(m);
    assert_eq!(engine.obs().sampling(), 0, "profiling must default off");
    let mut scores = vec![0f32; reqs.len()];
    for _ in 0..3 {
        engine.score(&reqs, &mut scores);
    }
    let doc = engine.obs().stages_json();
    assert!(
        doc.get("stages").and_then(Json::as_arr).unwrap().is_empty(),
        "sampling 0 must capture no stage histograms"
    );
    let trace = engine.trace_json(16);
    assert!(
        trace.get("spans").and_then(Json::as_arr).unwrap().is_empty(),
        "sampling 0 must capture no spans"
    );
}

#[test]
fn measured_overhead_reaches_the_policy_block_and_its_budget_math() {
    let m = model(0x73);
    let reqs = requests(&m, 8, 3);
    let mut scores = vec![0f32; reqs.len()];

    // Unpinned: after enough profiled batches every site is warm, and —
    // with every site still at Full (no controller tick ran) — the
    // estimated overhead IS the measured value: the budget math runs on
    // live numbers, not the static class prior.
    let engine = Engine::new(model(0x73)).with_policy(PolicyConfig::default());
    engine.obs().set_sampling(1);
    for _ in 0..6 {
        engine.score(&reqs, &mut scores);
    }
    let snap = engine.metrics_snapshot();
    let sites = snap.path(&["policy", "sites"]).and_then(Json::as_arr).unwrap();
    assert!(!sites.is_empty());
    for row in sites {
        let label = row.get("site").and_then(Json::as_str).unwrap();
        let measured = row
            .get("overhead_measured")
            .and_then(Json::as_f64)
            .unwrap_or_else(|| panic!("site {label} still cold after 6 profiled batches"));
        assert!(
            (0.0..=10.0).contains(&measured),
            "site {label}: measured overhead {measured} out of range"
        );
        assert_eq!(row.get("mode").and_then(Json::as_str), Some("full"));
        let est = row.get("overhead_est").and_then(Json::as_f64).unwrap();
        assert!(
            (est - measured).abs() < 1e-12,
            "site {label}: overhead_est {est} must equal the live measured {measured}"
        );
    }

    // Pinned: the budget math stays on the static prior, but the
    // measured value remains visible so prior/reality drift can be seen.
    let pinned = Engine::new(model(0x73)).with_policy(PolicyConfig {
        pin_unit_costs: true,
        ..PolicyConfig::default()
    });
    pinned.obs().set_sampling(1);
    for _ in 0..6 {
        pinned.score(&reqs, &mut scores);
    }
    let snap = pinned.metrics_snapshot();
    let cfg = PolicyConfig::default();
    for row in snap.path(&["policy", "sites"]).and_then(Json::as_arr).unwrap() {
        let label = row.get("site").and_then(Json::as_str).unwrap();
        assert!(
            row.get("overhead_measured").and_then(Json::as_f64).is_some(),
            "site {label}: pinning must not hide the measured overhead"
        );
        let est = row.get("overhead_est").and_then(Json::as_f64).unwrap();
        let prior = if label.starts_with("gemm/") {
            cfg.unit_costs.gemm_full_overhead
        } else {
            cfg.unit_costs.eb_full_overhead
        };
        assert!(
            (est - prior).abs() < 1e-12,
            "pinned site {label} must budget on the static prior, got est {est}"
        );
    }
}

#[test]
fn server_exposes_trace_prom_and_cursored_events() {
    let m = model(0x74);
    let reqs = score_requests(&m, 6, 4);
    let engine = Arc::new(Engine::new(m));
    engine.obs().set_sampling(1);
    let server = Server::start(
        "127.0.0.1:0",
        Arc::clone(&engine),
        BatchPolicy {
            max_batch: 4,
            max_wait: Duration::from_millis(1),
            max_queue: 64,
            loops: 1,
        },
    )
    .unwrap();
    let mut client = Client::connect(&server.addr).unwrap();
    for req in &reqs {
        let resp = client.score(req).unwrap();
        assert_eq!(resp.id, req.id);
    }

    // trace: the profiled request path left spans, including the two
    // server-side stages (request parse, batcher queue wait).
    let trace = client.trace(128).unwrap();
    assert!(!trace.get("spans").and_then(Json::as_arr).unwrap().is_empty());
    let names: Vec<&str> = trace
        .path(&["stages", "stages"])
        .and_then(Json::as_arr)
        .unwrap()
        .iter()
        .filter_map(|s| s.get("stage").and_then(Json::as_str))
        .collect();
    assert!(names.contains(&"parse"), "stages seen: {names:?}");
    assert!(names.contains(&"queue_wait"), "stages seen: {names:?}");

    // prom: the whole snapshot as text exposition, one round trip.
    let text = client.prom().unwrap();
    assert!(text.contains("dlrm_requests 6"), "{text}");
    assert!(text.contains("dlrm_obs_sample_1_in 1"), "{text}");

    // events cursor: clean traffic journals nothing, cursor sits at 0 —
    // and the wrap marker is explicit even then: gap 0 means "the ring
    // never overwrote past your cursor", not "field absent".
    let ev = client.events_since(0).unwrap();
    assert!(ev.get("events").and_then(Json::as_arr).unwrap().is_empty());
    assert_eq!(ev.get("next_cursor").and_then(Json::as_usize), Some(0));
    assert_eq!(ev.get("gap").and_then(Json::as_usize), Some(0));
    server.stop();
}

#[test]
fn flight_recorder_freezes_a_complete_black_box_on_severe_fault() {
    use dlrm_abft::detect::Severity;
    let m = model(0x76);
    let reqs = requests(&m, 8, 6);
    // tick ZERO = manual controller ticks: the policy lock is
    // uncontended, so the freeze-time snapshot closure always lands.
    let engine = Engine::new(model(0x76))
        .with_shards(ShardPlan::hash_placement(2, 1, 2), 64)
        .with_policy(PolicyConfig { tick: Duration::ZERO, ..PolicyConfig::default() });
    engine.obs().set_sampling(1);
    let rec = engine.arm_flightrec(4, Severity::Significant);
    let mut scores = vec![0f32; reqs.len()];
    // Warm clean batches: spans (per-layer GEMM + verify, with kernel
    // tier labels) populate the rings before any fault.
    for _ in 0..2 {
        engine.score(&reqs, &mut scores);
    }
    assert_eq!(rec.captures_taken(), 0, "clean traffic must not freeze");

    // Persistent corruption of replica 0's copy of table 0: every
    // checked bag flags hard, fails same-replica retry, and recovers by
    // failover — Severe events with the batch's flow stamped.
    let store = engine.shard_store().unwrap();
    for row in 0..2_000 {
        store.flip_table_byte(0, 0, row * 16, 0x80);
    }
    let mark = engine.journal().total();
    for _ in 0..4 {
        engine.score(&reqs, &mut scores);
        if rec.captures_taken() > 0 {
            break;
        }
    }
    let severe = engine
        .journal()
        .since(mark)
        .iter()
        .filter(|e| e.severity >= Severity::Significant)
        .count();
    assert!(severe > 0, "corruption must journal Severe events");
    assert_eq!(rec.captures_taken(), severe as u64, "one freeze per Severe event");

    // The newest capture is a complete, self-contained post-mortem.
    let cap = rec.capture_json(rec.captures_taken()).expect("newest capture resident");
    assert_eq!(
        cap.path(&["event", "severity"]).and_then(Json::as_str),
        Some("significant"),
        "capture must embed the triggering event"
    );
    let flow = cap.get("flow").and_then(Json::as_usize).unwrap();
    assert!(flow > 0, "event must carry the scoring batch's flow id");
    // Causal timeline: non-empty, every span shares the event's flow
    // tag, and the faulting request's recovery rung is on it.
    let tag = cap.get("flow_tag").and_then(Json::as_usize).unwrap();
    let timeline = cap.get("flow_timeline").and_then(Json::as_arr).unwrap();
    assert!(!timeline.is_empty(), "flow timeline must hold the faulting batch's spans");
    let mut stages = Vec::new();
    for span in timeline {
        assert_eq!(span.get("flow").and_then(Json::as_usize), Some(tag));
        stages.push(span.get("stage").and_then(Json::as_str).unwrap().to_string());
    }
    assert!(
        stages.iter().any(|s| s == "failover_replica"),
        "recovery rung span must correlate by flow: {stages:?}"
    );
    // The wider span window keeps the warm batches' verify spans, each
    // labeled with the dispatched kernel tier.
    let spans = cap.get("spans").and_then(Json::as_arr).unwrap();
    assert!(
        spans.iter().any(|s| {
            s.get("stage").and_then(Json::as_str) == Some("verify") && s.get("tier").is_some()
        }),
        "verify spans must carry kernel tier labels"
    );
    // Control planes rode along: policy modes + shard health + the
    // per-layer kernel dispatch snapshot.
    assert!(cap.get("policy").is_some_and(|p| *p != Json::Null), "policy snapshot missing");
    assert!(cap.get("shards").is_some_and(|s| *s != Json::Null), "shard snapshot missing");
    assert!(
        !cap.get("kernel_tiers").and_then(Json::as_arr).unwrap().is_empty(),
        "kernel tier snapshot missing"
    );
    // And the armed recorder surfaces in the metrics snapshot.
    let snap = engine.metrics_snapshot();
    assert!(
        snap.path(&["flightrec", "captures"]).and_then(Json::as_usize).unwrap() >= 1,
        "metrics snapshot must carry the recorder status"
    );
}

#[test]
fn capture_pool_evicts_oldest_and_never_blocks() {
    use dlrm_abft::detect::{Detector, Resolution, Severity, SiteId, UnitRef};
    let engine = Engine::new(model(0x77));
    let rec = engine.arm_flightrec(2, Severity::Significant);
    for i in 0..5u32 {
        engine.event_sink().emit(
            SiteId::Gemm(i % 2),
            UnitRef::GemmRow { row: i },
            Detector::GemmChecksum,
            Severity::Significant,
            Resolution::DetectedOnly,
        );
    }
    assert_eq!(rec.captures_taken(), 5);
    // Pool of 2: the newest two captures are resident; older ones were
    // evicted by slot reuse — never blocked on, never grown.
    assert!(rec.capture_json(4).is_some());
    assert!(rec.capture_json(5).is_some());
    for id in 1..=3u64 {
        assert!(rec.capture_json(id).is_none(), "capture {id} must be evicted");
    }
    let status = rec.status_json();
    assert_eq!(status.get("resident").and_then(Json::as_usize), Some(2));
    assert_eq!(status.get("missed").and_then(Json::as_usize), Some(0));
    // Below the severity floor: journaled, never frozen.
    engine.event_sink().emit(
        SiteId::Gemm(0),
        UnitRef::GemmRow { row: 9 },
        Detector::GemmChecksum,
        Severity::NearBound,
        Resolution::DetectedOnly,
    );
    assert_eq!(rec.captures_taken(), 5, "below-floor events must not freeze");
    assert_eq!(engine.journal().total(), 6, "every event still journals");
}

fn has_num(j: &Json) -> bool {
    match j {
        Json::Num(_) | Json::Bool(_) => true,
        Json::Obj(m) => m.iter().any(|(_, v)| has_num(v)),
        Json::Arr(a) => a.iter().any(has_num),
        _ => false,
    }
}

#[test]
fn prom_text_covers_every_numeric_snapshot_block() {
    let m = model(0x75);
    let reqs = requests(&m, 8, 5);
    let engine = Engine::new(model(0x75))
        .with_shards(ShardPlan::hash_placement(2, 2, 2), 64)
        .with_policy(PolicyConfig::default());
    engine.obs().set_sampling(1);
    let mut scores = vec![0f32; reqs.len()];
    for _ in 0..3 {
        engine.score(&reqs, &mut scores);
    }
    let snap = engine.metrics_snapshot();
    let text = engine.prom_text();
    let Json::Obj(map) = &snap else {
        panic!("snapshot must be an object")
    };
    // The walker is generic: every snapshot block with a numeric leaf —
    // counters, latency, events, obs, shards, policy — must surface
    // under its own `dlrm_<block>` prefix.
    for (key, val) in map {
        if has_num(val) {
            let prefix = format!("dlrm_{key}");
            assert!(text.contains(&prefix), "snapshot block {key} missing from prom text");
        }
    }
    // Per-site policy rows keep their identity as a label.
    assert!(
        text.contains("dlrm_policy_sites_overhead_est{site=\"gemm/0\"}"),
        "{text}"
    );
    // Span-ring health rides the obs block: per-lane fill watermarks and
    // drop/overwrite counters are first-class prom series.
    assert!(
        text.contains("dlrm_obs_rings_overwritten_total"),
        "ring overwrite counter missing from prom text:\n{text}"
    );
    assert!(
        text.contains("dlrm_obs_rings_lanes_fill{id="),
        "per-lane fill watermark missing from prom text:\n{text}"
    );
}
