//! Integration: ABFT-GEMM over the full Fig-5 shape grid — clean runs,
//! injected runs, and payload equivalence with the unprotected kernel.

use dlrm_abft::abft::{AbftGemm, RowCorrection, GROUP_WIDTH};
use dlrm_abft::fault::campaign::fig5_shapes;
use dlrm_abft::gemm::{
    gemm_exec, gemm_exec_into, gemm_exec_into_scalar, gemm_exec_into_st, PackedB,
};
use dlrm_abft::util::rng::Pcg32;

#[test]
fn full_fig5_grid_clean_and_equivalent() {
    let mut rng = Pcg32::new(0xF165);
    for (m, n, k) in fig5_shapes() {
        // Cap the largest shapes to keep the debug-profile test fast; the
        // release bench covers full size.
        let (m, n, k) = (m.min(50), n.min(512), k.min(512));
        let mut a = vec![0u8; m * k];
        let mut b = vec![0i8; k * n];
        rng.fill_u8(&mut a);
        rng.fill_i8(&mut b);
        let abft = AbftGemm::new(&b, k, n);
        let nt = abft.n_total();
        let (c, verdict) = abft.exec(&a, m);
        assert!(verdict.clean(), "shape ({m},{n},{k}) false positive");
        let plain = gemm_exec(&a, &PackedB::pack(&b, k, n), m);
        for i in 0..m {
            assert_eq!(
                &c[i * nt..i * nt + n],
                &plain[i * n..(i + 1) * n],
                "payload mismatch at shape ({m},{n},{k}) row {i}"
            );
        }
    }
}

#[test]
fn grid_injected_bitflips_detected() {
    let mut rng = Pcg32::new(0xF166);
    let mut detected = 0usize;
    let mut total = 0usize;
    for (m, n, k) in fig5_shapes() {
        let (m, n, k) = (m.min(20), n.min(256), k.min(256));
        let mut a = vec![0u8; m * k];
        let mut b = vec![0i8; k * n];
        rng.fill_u8(&mut a);
        rng.fill_i8(&mut b);
        let abft = AbftGemm::new(&b, k, n);
        let (mut c, _) = abft.exec(&a, m);
        let idx = rng.gen_range(0, m) * abft.n_total() + rng.gen_range(0, n);
        c[idx] ^= 1 << rng.gen_range_u32(31);
        total += 1;
        if !abft.verify(&c, m).clean() {
            detected += 1;
        }
    }
    // §IV-C2 model 1: bit flips in C_temp are detected with certainty.
    assert_eq!(detected, total);
}

#[test]
fn correction_grid_boundary_columns_all_dispatch_paths() {
    // PR-6 correction at the layout boundaries that could break the
    // group algebra: the first column, the last column of the first
    // panel/group and the first of the next, the ragged tail of n, and
    // the Eq-3b checksum column itself — under every kernel dispatch
    // path (parallel + SIMD, single-thread SIMD, scalar). The integer
    // accumulators must agree bit-for-bit across paths, and a corrected
    // row must equal both the full recompute and the clean run exactly.
    let mut rng = Pcg32::new(0xF167);
    // n exactly one group; one past; ragged last group; multi-group;
    // odd (pair-tail) k; k = 1 (degenerate pair tail).
    let shapes = [
        (4usize, 32usize, 48usize),
        (4, 33, 48),
        (3, 95, 37),
        (8, 256, 64),
        (5, 64, 31),
        (2, 40, 1),
    ];
    let paths: [fn(&[u8], &PackedB, usize, &mut [i32]); 3] =
        [gemm_exec_into, gemm_exec_into_st, gemm_exec_into_scalar];
    for (m, n, k) in shapes {
        let mut a = vec![0u8; m * k];
        let mut b = vec![0i8; k * n];
        rng.fill_u8(&mut a);
        rng.fill_i8(&mut b);
        let abft = AbftGemm::new(&b, k, n);
        let nt = abft.n_total();
        let clean = abft.exec(&a, m).0;
        let mut cols = vec![0, n - 1, n];
        if n > GROUP_WIDTH {
            cols.extend([GROUP_WIDTH - 1, GROUP_WIDTH]);
        }
        for exec in paths {
            let mut c = vec![0i32; m * nt];
            exec(&a, &abft.packed, m, &mut c);
            assert_eq!(c, clean, "dispatch paths disagree at ({m},{n},{k})");
            for &col in &cols {
                let row = rng.gen_range(0, m);
                let mut corrupt = c.clone();
                corrupt[row * nt + col] ^= 1 << rng.gen_range_u32(31);
                assert_eq!(
                    abft.verify(&corrupt, m).corrupted_rows,
                    vec![row],
                    "({m},{n},{k}) col {col} not flagged"
                );
                let mut recomputed = corrupt.clone();
                abft.recompute_row(&a, row, &mut recomputed, m);
                let got = abft.correct_row(&a, row, &mut corrupt, m);
                assert!(
                    matches!(got, RowCorrection::Corrected { col: named, .. } if named == col),
                    "({m},{n},{k}) col {col}: {got:?}"
                );
                assert_eq!(
                    corrupt, recomputed,
                    "corrected != recomputed at ({m},{n},{k}) col {col}"
                );
                assert_eq!(corrupt, clean, "corrected != clean at ({m},{n},{k}) col {col}");
            }
        }
    }
}

#[test]
fn theoretical_overhead_small_for_paper_shapes() {
    for (m, n, k) in fig5_shapes() {
        let oh = AbftGemm::theoretical_overhead(m, n, k);
        // Amortized-encode overhead (verify + extra column only) is what
        // the figure measures; the closed form includes encode, so allow
        // the m=1 shapes their 1/(2m) = 50% term.
        let amortized = 1.0 / n as f64 + 1.0 / (2.0 * k as f64);
        assert!(amortized < 0.20, "shape ({m},{n},{k}) amortized {amortized}");
        assert!(oh < 0.52, "shape ({m},{n},{k}) full {oh}");
    }
}
