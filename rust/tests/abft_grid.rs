//! Integration: ABFT-GEMM over the full Fig-5 shape grid — clean runs,
//! injected runs, and payload equivalence with the unprotected kernel.

use dlrm_abft::abft::AbftGemm;
use dlrm_abft::fault::campaign::fig5_shapes;
use dlrm_abft::gemm::{gemm_exec, PackedB};
use dlrm_abft::util::rng::Pcg32;

#[test]
fn full_fig5_grid_clean_and_equivalent() {
    let mut rng = Pcg32::new(0xF165);
    for (m, n, k) in fig5_shapes() {
        // Cap the largest shapes to keep the debug-profile test fast; the
        // release bench covers full size.
        let (m, n, k) = (m.min(50), n.min(512), k.min(512));
        let mut a = vec![0u8; m * k];
        let mut b = vec![0i8; k * n];
        rng.fill_u8(&mut a);
        rng.fill_i8(&mut b);
        let abft = AbftGemm::new(&b, k, n);
        let (c, verdict) = abft.exec(&a, m);
        assert!(verdict.clean(), "shape ({m},{n},{k}) false positive");
        let plain = gemm_exec(&a, &PackedB::pack(&b, k, n), m);
        for i in 0..m {
            assert_eq!(
                &c[i * (n + 1)..i * (n + 1) + n],
                &plain[i * n..(i + 1) * n],
                "payload mismatch at shape ({m},{n},{k}) row {i}"
            );
        }
    }
}

#[test]
fn grid_injected_bitflips_detected() {
    let mut rng = Pcg32::new(0xF166);
    let mut detected = 0usize;
    let mut total = 0usize;
    for (m, n, k) in fig5_shapes() {
        let (m, n, k) = (m.min(20), n.min(256), k.min(256));
        let mut a = vec![0u8; m * k];
        let mut b = vec![0i8; k * n];
        rng.fill_u8(&mut a);
        rng.fill_i8(&mut b);
        let abft = AbftGemm::new(&b, k, n);
        let (mut c, _) = abft.exec(&a, m);
        let idx = rng.gen_range(0, m) * (n + 1) + rng.gen_range(0, n);
        c[idx] ^= 1 << rng.gen_range_u32(31);
        total += 1;
        if !abft.verify(&c, m).clean() {
            detected += 1;
        }
    }
    // §IV-C2 model 1: bit flips in C_temp are detected with certainty.
    assert_eq!(detected, total);
}

#[test]
fn theoretical_overhead_small_for_paper_shapes() {
    for (m, n, k) in fig5_shapes() {
        let oh = AbftGemm::theoretical_overhead(m, n, k);
        // Amortized-encode overhead (verify + extra column only) is what
        // the figure measures; the closed form includes encode, so allow
        // the m=1 shapes their 1/(2m) = 50% term.
        let amortized = 1.0 / n as f64 + 1.0 / (2.0 * k as f64);
        assert!(amortized < 0.20, "shape ({m},{n},{k}) amortized {amortized}");
        assert!(oh < 0.52, "shape ({m},{n},{k}) full {oh}");
    }
}
