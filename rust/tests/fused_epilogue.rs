//! Bit-exactness grid for the fused GEMM requantize/ReLU epilogue (PR 3):
//! on every dispatch path (AVX2 fused, scalar fallback, row-parallel) the
//! fused kernel must produce (a) the identical i32 `C_temp` as the plain
//! GEMM and (b) the identical u8 output as the two-pass scalar
//! requantize(+ReLU) flow — including when the pack carries the ABFT
//! checksum column, which is computed but never requantized (§IV-A3).

use dlrm_abft::abft::AbftGemm;
use dlrm_abft::dlrm::{AbftLinear, Protection};
use dlrm_abft::gemm::{
    gemm_exec, gemm_requant_exec_into, gemm_requant_exec_into_scalar, set_kernel_tier_override,
    simd_active, KernelTier, PackedB,
};
use dlrm_abft::quant::{
    quantize_slice_u8, requantize, requantize_cols_into, QParams, RequantEpilogue, RequantParams,
};
use dlrm_abft::util::rng::Pcg32;

fn rand_case(rng: &mut Pcg32, m: usize, k: usize, n: usize) -> (Vec<u8>, Vec<i8>) {
    let mut a = vec![0u8; m * k];
    let mut b = vec![0i8; k * n];
    rng.fill_u8(&mut a);
    rng.fill_i8(&mut b);
    (a, b)
}

fn qparams(rng: &mut Pcg32) -> (QParams, QParams, QParams) {
    let a = QParams::fit_u8(0.0, 1.0 + rng.next_f32() * 3.0);
    let b = QParams::fit_i8(-0.5 - rng.next_f32(), 0.5 + rng.next_f32());
    let c = QParams::fit_u8(-40.0 - rng.next_f32() * 200.0, 44.0 + rng.next_f32() * 200.0);
    (a, b, c)
}

/// Reference: plain GEMM, scalar requantize over the payload columns,
/// then the quantized ReLU clamp — the exact pre-PR3 two-pass pipeline.
fn two_pass_reference(
    a: &[u8],
    packed: &PackedB,
    m: usize,
    p: &RequantParams,
    relu_floor: u8,
) -> (Vec<i32>, Vec<u8>) {
    let c_temp = gemm_exec(a, packed, m);
    let n = packed.n;
    let mut out = if packed.extra_cols == 0 {
        requantize(&c_temp, m, n, p)
    } else {
        // Payload columns only: the Eq-3b checksum column and the PR-6
        // group checksum columns are computed but never requantized.
        let mut out = vec![0u8; m * n];
        requantize_cols_into(
            &c_temp,
            m,
            packed.n_total(),
            0..n,
            &p.a_row_sums,
            &p.b_col_sums,
            &p.spec(),
            0,
            &mut out,
        );
        out
    };
    for v in &mut out {
        if *v < relu_floor {
            *v = relu_floor;
        }
    }
    (c_temp, out)
}

/// RAII tier cap for the tier-parameterized grid: always restores "no
/// override" on drop so a failing grid can't leak a cap into the other
/// tests in this binary (which are cap-agnostic anyway — every tier is
/// bit-identical).
struct TierCap;

impl TierCap {
    fn set(tier: KernelTier) -> Self {
        set_kernel_tier_override(Some(tier));
        TierCap
    }
}

impl Drop for TierCap {
    fn drop(&mut self) {
        set_kernel_tier_override(None);
    }
}

/// The grid: shapes covering m=1, row pairs + odd row, panel boundaries
/// (n = 31 / 32 / 33 / 64 / 65), odd k (in-register tail fold), and the
/// GEMM_PAR_MIN_WORK crossing (row-parallel fused path); each × {plain,
/// checksum-augmented} × {ReLU on, off} — and the whole battery under
/// every kernel-tier cap (PR 8), since the fused flow now runs
/// tier-kernel + shared memory-sourced epilogue on the acc16/AVX-512
/// tiers and must keep producing the same bytes.
#[test]
fn fused_epilogue_bit_identical_to_two_pass() {
    let mut rng = Pcg32::new(0xF05E);
    let shapes: &[(usize, usize, usize)] = &[
        (1, 1, 1),
        (1, 64, 32),
        (2, 7, 5),
        (3, 64, 31),
        (2, 33, 32), // odd k: the tail row folds into registers
        (5, 64, 33),
        (4, 128, 64),
        (3, 129, 65),
        (8, 255, 96),
        (19, 384, 320), // crosses GEMM_PAR_MIN_WORK → row-parallel fused
    ];
    for cap in [
        KernelTier::Scalar,
        KernelTier::Avx2,
        KernelTier::Acc16,
        KernelTier::Avx512,
    ] {
        let _cap = TierCap::set(cap);
        for &(m, k, n) in shapes {
            for with_checksum in [false, true] {
                for relu in [false, true] {
                    let (a, b) = rand_case(&mut rng, m, k, n);
                    let (qa, qb, qc) = qparams(&mut rng);
                    let packed = if with_checksum {
                        AbftGemm::new(&b, k, n).packed
                    } else {
                        PackedB::pack(&b, k, n)
                    };
                    let p = RequantParams::prepare(&a, &b, m, k, n, qa, qb, qc);
                    let relu_floor = if relu { qc.quantize_u8(0.0) } else { 0 };
                    let (want_c, want_out) = two_pass_reference(&a, &packed, m, &p, relu_floor);

                    let nt = packed.n_total();
                    let epi = RequantEpilogue {
                        spec: p.spec(),
                        a_row_sums: &p.a_row_sums,
                        b_col_sums: &p.b_col_sums,
                        n_out: n,
                        relu_floor,
                    };
                    let tag =
                        format!("cap={cap:?} ({m},{k},{n}) checksum={with_checksum} relu={relu}");

                    let mut c_fused = vec![0i32; m * nt];
                    let mut out_fused = vec![0u8; m * n];
                    gemm_requant_exec_into(&a, &packed, m, &epi, &mut c_fused, &mut out_fused);
                    assert_eq!(c_fused, want_c, "fused C_temp diverged {tag}");
                    assert_eq!(out_fused, want_out, "fused output diverged {tag}");

                    let mut c_scalar = vec![0i32; m * nt];
                    let mut out_scalar = vec![0u8; m * n];
                    gemm_requant_exec_into_scalar(
                        &a, &packed, m, &epi, &mut c_scalar, &mut out_scalar,
                    );
                    assert_eq!(c_scalar, want_c, "scalar-forced C_temp diverged {tag}");
                    assert_eq!(out_scalar, want_out, "scalar-forced output diverged {tag}");
                }
            }
        }
    }
    eprintln!("fused grid done (avx2 fused path active: {})", simd_active());
}

/// Saturated inputs push the accumulator (and the affine correction) to
/// its extremes — the epilogue's clamp and the in-register odd-k tail
/// must stay exact there too.
#[test]
fn fused_epilogue_saturated_inputs_exact() {
    for (k, fill) in [(64usize, 127i8), (65, -128), (63, -127)] {
        let (m, n) = (3usize, 64usize);
        let a = vec![255u8; m * k];
        let b = vec![fill; k * n];
        let packed = PackedB::pack(&b, k, n);
        let qa = QParams::fit_u8(0.0, 4.0);
        let qb = QParams::fit_i8(-1.0, 1.0);
        let qc = QParams::fit_u8(-100.0, 120.0);
        let p = RequantParams::prepare(&a, &b, m, k, n, qa, qb, qc);
        let (want_c, want_out) = two_pass_reference(&a, &packed, m, &p, 0);
        let epi = RequantEpilogue {
            spec: p.spec(),
            a_row_sums: &p.a_row_sums,
            b_col_sums: &p.b_col_sums,
            n_out: n,
            relu_floor: 0,
        };
        let mut c = vec![0i32; m * n];
        let mut out = vec![0u8; m * n];
        gemm_requant_exec_into(&a, &packed, m, &epi, &mut c, &mut out);
        assert_eq!(c, want_c, "k={k} fill={fill}");
        assert_eq!(out, want_out, "k={k} fill={fill}");
    }
}

/// The layer-level contract: `AbftLinear::forward` (now fused inside)
/// must still match the hand-composed two-pass pipeline on protected and
/// unprotected paths, and detection semantics must survive the fusion —
/// a corrupted packed weight is flagged from the stored i32 accumulator.
#[test]
fn abft_linear_fused_matches_manual_two_pass() {
    let mut rng = Pcg32::new(0xAB1);
    for (m, k, n) in [(1usize, 48usize, 32usize), (6, 96, 40), (4, 33, 64)] {
        for protection in [Protection::Off, Protection::Detect, Protection::DetectRecompute] {
            for relu in [false, true] {
                let layer = AbftLinear::random(k, n, relu, protection, &mut rng);
                let xf: Vec<f32> = (0..m * k).map(|_| rng.next_f32()).collect();
                let (x, xp) = quantize_slice_u8(&xf);
                let (y, rep) = layer.forward(&x, m, xp);
                assert_eq!(rep.rows_flagged, 0, "clean layer must not flag");

                // Manual two-pass: protected GEMM (or plain), scalar
                // requantize excluding the checksum column, then ReLU.
                let p = layer.requant_params(&x, m, xp);
                let nt = layer.abft().packed.n_total();
                let packed = if protection.enabled() {
                    layer.abft().packed.clone()
                } else {
                    PackedB::pack(
                        &layer.abft().packed.to_row_major()[..] // row-major k×nt
                            .chunks(nt)
                            .flat_map(|r| r[..n].iter().copied())
                            .collect::<Vec<i8>>(),
                        k,
                        n,
                    )
                };
                let relu_floor = if relu { layer.out_qparams.quantize_u8(0.0) } else { 0 };
                let (_, want) = two_pass_reference(&x, &packed, m, &p, relu_floor);
                assert_eq!(y, want, "({m},{k},{n}) prot={protection:?} relu={relu}");
            }
        }
    }
}

/// Detection through the fused path: corrupt a packed payload byte and
/// the verdict (computed from the stored `C_temp`) must still fire.
#[test]
fn fused_path_preserves_detection() {
    let mut rng = Pcg32::new(0xDE7);
    let (m, k, n) = (6usize, 48usize, 40usize);
    let mut layer = AbftLinear::random(k, n, true, Protection::Detect, &mut rng);
    let xf: Vec<f32> = (0..m * k).map(|_| rng.next_f32()).collect();
    let (x, xp) = quantize_slice_u8(&xf);
    let idx = layer.abft().packed.offset(5, 3);
    let data = layer.abft_mut().packed.data_mut();
    data[idx] = (data[idx] as u8 ^ 0x40) as i8;
    let (_, rep) = layer.forward(&x, m, xp);
    assert!(rep.rows_flagged > 0, "corruption must be flagged through the fused path");
}

/// Quantization-lattice edge sweep: drive values that land arbitrarily
/// close to rounding boundaries through both paths. With α_C chosen so
/// code boundaries fall on representable halves, ties are exercised.
#[test]
fn fused_epilogue_rounding_boundary_sweep() {
    // α = 2.0, β = -256: real values land on integers and exact .5
    // points depending on c_temp parity — round-half-away ties galore.
    let qa = QParams { alpha: 1.0, beta: 0.0 };
    let qb = QParams { alpha: 1.0, beta: 0.0 };
    let qc = QParams { alpha: 2.0, beta: -256.0 };
    let (m, k, n) = (8usize, 1usize, 64usize);
    // a: single k so c_temp[i][j] = a[i] * b[j]; choose values to sweep
    // the output lattice including exact-tie points.
    let a: Vec<u8> = (0..m as u8).map(|v| v * 3 + 1).collect();
    let b: Vec<i8> = (0..n).map(|j| (j as i32 - 32) as i8).collect();
    let packed = PackedB::pack(&b, k, n);
    let p = RequantParams::prepare(&a, &b, m, k, n, qa, qb, qc);
    let (want_c, want_out) = two_pass_reference(&a, &packed, m, &p, 0);
    let epi = RequantEpilogue {
        spec: p.spec(),
        a_row_sums: &p.a_row_sums,
        b_col_sums: &p.b_col_sums,
        n_out: n,
        relu_floor: 0,
    };
    let mut c = vec![0i32; m * n];
    let mut out = vec![0u8; m * n];
    gemm_requant_exec_into(&a, &packed, m, &epi, &mut c, &mut out);
    assert_eq!(c, want_c);
    assert_eq!(out, want_out, "tie-prone lattice must round identically");
}
