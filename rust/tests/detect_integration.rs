//! Integration tests for the unified fault-event pipeline (PR 5):
//! inject one fault per detection-site class and assert the journal
//! records exactly the matching [`FaultEvent`] — correct site, detector,
//! severity, and ladder resolution — plus journal wrap behavior and the
//! engine-level retry trail.
//!
//! Site classes covered: GEMM row verify, the BoundOnly batch aggregate,
//! the local (unsharded) fused EB check, the shard router
//! (failover and R=1 degrade), and the scrubber (sharded self-heal,
//! sharded quarantine, and local report-only). The steady-state zero-allocation property with
//! the journal attached is enforced separately in
//! `rust/tests/zero_alloc.rs` (engines always attach a sink).

use dlrm_abft::abft::AbftGemm;
use dlrm_abft::coordinator::Engine;
use dlrm_abft::detect::{
    recovery, Detector, EventSink, Recovery, Resolution, Severity, SiteClass, SiteCtx, SiteId,
    UnitRef, LOCAL_REPLICA,
};
use dlrm_abft::dlrm::{AbftLinear, DlrmConfig, DlrmModel, Protection, TableConfig};
use dlrm_abft::policy::DetectionMode;
use dlrm_abft::quant::{QParams, RequantEpilogue, RequantSpec};
use dlrm_abft::shard::{ShardPlan, ShardRouter, ShardStore};
use dlrm_abft::util::json::Json;
use dlrm_abft::util::rng::Pcg32;
use dlrm_abft::util::scratch::GemmScratch;
use std::sync::Arc;

/// A layer whose packed-B payload byte at logical (p, j) is XORed with
/// `mask` — a deterministic persistent operand fault.
fn corrupted_layer(k: usize, n: usize, mask: u8, protection: Protection) -> AbftLinear {
    let mut rng = Pcg32::new(0x5EED5);
    let mut layer = AbftLinear::random(k, n, false, protection, &mut rng);
    let idx = layer.abft().packed.offset(3, 5);
    let data = layer.abft_mut().packed.data_mut();
    data[idx] = (data[idx] as u8 ^ mask) as i8;
    layer
}

#[test]
fn gemm_row_fault_journals_one_escalated_event() {
    // m = 1, x = const 200: the B-payload flip of bit 6 shifts the row
    // residual by 200·(±64) = ∓12800 — detected (12800 % 127 ≠ 0), and
    // the recompute re-reads the same corrupt operand, so the ladder
    // escalates to the engine's batch retry with worst-case severity.
    let (k, n, m) = (32usize, 16usize, 1usize);
    let layer = corrupted_layer(k, n, 0x40, Protection::DetectRecompute);
    let sink = EventSink::with_capacity(16);
    let x = vec![200u8; m * k];
    let mut out = vec![0u8; m * n];
    let mut scratch = GemmScratch::default();
    let rep = layer.forward_policied(
        &x,
        m,
        QParams::fit_u8(0.0, 1.0),
        DetectionMode::Full,
        SiteCtx::new(&sink, SiteId::Gemm(7), None),
        &mut scratch,
        &mut out,
    );
    assert_eq!(rep.rows_flagged, 1);
    assert_eq!(rep.rows_recomputed, 1);
    let j = sink.journal().unwrap();
    assert_eq!(j.total(), 1, "exactly one event for one injected fault");
    let ev = j.recent(1)[0];
    assert_eq!(ev.site, SiteId::Gemm(7));
    assert_eq!(ev.unit, UnitRef::GemmRow { row: 0 });
    assert_eq!(ev.detector, Detector::GemmChecksum);
    assert_eq!(ev.severity, Severity::Significant, "operand corruption is worst-case");
    assert_eq!(ev.resolution, Resolution::Escalated(Recovery::RetryBatch));
}

#[test]
fn gemm_detect_only_fault_journals_detected_only() {
    let (k, n, m) = (32usize, 16usize, 1usize);
    let layer = corrupted_layer(k, n, 0x40, Protection::Detect);
    let sink = EventSink::with_capacity(16);
    let x = vec![200u8; m * k];
    let mut out = vec![0u8; m * n];
    let mut scratch = GemmScratch::default();
    let rep = layer.forward_policied(
        &x,
        m,
        QParams::fit_u8(0.0, 1.0),
        DetectionMode::Full,
        SiteCtx::new(&sink, SiteId::Gemm(0), None),
        &mut scratch,
        &mut out,
    );
    assert_eq!(rep.rows_flagged, 1);
    assert_eq!(rep.rows_recomputed, 0);
    let ev = sink.journal().unwrap().recent(1)[0];
    assert_eq!(ev.resolution, Resolution::DetectedOnly);
    assert_eq!(ev.detector, Detector::GemmChecksum);
}

#[test]
fn bound_only_aggregate_journals_batch_aggregate_event() {
    let (k, n, m) = (32usize, 16usize, 4usize);
    let layer = corrupted_layer(k, n, 0x40, Protection::DetectRecompute);
    let sink = EventSink::with_capacity(16);
    // Same x for every row: the per-row deltas share a sign, so they
    // cannot cancel in the aggregate.
    let x = vec![200u8; m * k];
    let mut out = vec![0u8; m * n];
    let mut scratch = GemmScratch::default();
    let rep = layer.forward_policied(
        &x,
        m,
        QParams::fit_u8(0.0, 1.0),
        DetectionMode::BoundOnly,
        SiteCtx::new(&sink, SiteId::Gemm(2), None),
        &mut scratch,
        &mut out,
    );
    assert_eq!(rep.rows_flagged, 1, "aggregate reports one flag");
    assert_eq!(rep.rows_recomputed, 0, "the aggregate cannot name a row");
    let j = sink.journal().unwrap();
    assert_eq!(j.total(), 1);
    let ev = j.recent(1)[0];
    assert_eq!(ev.site, SiteId::Gemm(2));
    assert_eq!(ev.unit, UnitRef::BatchAggregate);
    assert_eq!(ev.detector, Detector::GemmAggregate);
    assert_eq!(ev.severity, Severity::Significant);
    assert_eq!(ev.resolution, Resolution::Escalated(Recovery::RetryBatch));
}

#[test]
fn transient_gemm_fault_recovers_at_the_recompute_rung() {
    // The `RecomputeUnit` rung in isolation: corrupt the 32-bit
    // accumulator (a transient compute fault), recompute the row through
    // `recovery::recompute_gemm_row`, and verify the residual shift it
    // classifies severity from is exactly the injected delta.
    let mut rng = Pcg32::new(0x7A31);
    let (m, k, n) = (3usize, 24usize, 12usize);
    let mut b = vec![0i8; k * n];
    rng.fill_i8(&mut b);
    let mut x = vec![0u8; m * k];
    rng.fill_u8(&mut x);
    let abft = AbftGemm::new(&b, k, n);
    let (mut c_temp, verdict) = abft.exec(&x, m);
    assert!(verdict.clean());
    let clean = c_temp.clone();
    let before_clean = abft.row_residual(&c_temp, m, 1);
    c_temp[abft.n_total() + 2] += 5_000; // row 1, transient delta +5000
    let before = abft.row_residual(&c_temp, m, 1);
    assert_eq!(before - before_clean, 5_000);
    // Re-requantization target for the repaired row.
    let a_row_sums: Vec<i32> = (0..m)
        .map(|i| x[i * k..(i + 1) * k].iter().map(|&v| v as i32).sum())
        .collect();
    let spec = RequantSpec::new(
        QParams::fit_u8(0.0, 1.0),
        QParams::fit_u8(-1.0, 1.0),
        QParams::fit_u8(-4.0, 4.0),
        k,
    );
    let mut b_col_sums = vec![0i32; n];
    for p in 0..k {
        for jj in 0..n {
            b_col_sums[jj] += b[p * n + jj] as i32;
        }
    }
    let mut out = vec![0u8; m * n];
    let epi = RequantEpilogue {
        spec,
        a_row_sums: &a_row_sums,
        b_col_sums: &b_col_sums,
        n_out: n,
        relu_floor: 0,
    };
    let ok = recovery::recompute_gemm_row(&abft, &x, 1, m, &epi, &mut c_temp, &mut out);
    assert!(ok, "a transient accumulator fault must clear on recompute");
    assert_eq!(c_temp, clean, "recompute restores the exact accumulator");
    let after = abft.row_residual(&c_temp, m, 1);
    assert_eq!(before - after, 5_000, "the residual shift is the injected delta");
    assert_eq!(Severity::from_gemm_delta(before - after), Severity::Significant);
    assert_eq!(Severity::from_gemm_delta(7), Severity::NearBound);
}

fn eb_model(tables: usize, protection: Protection) -> DlrmModel {
    DlrmModel::random(DlrmConfig {
        num_dense: 4,
        embedding_dim: 8,
        bottom_mlp: vec![12, 8],
        top_mlp: vec![12],
        tables: vec![TableConfig { rows: 120, pooling: 4 }; tables],
        protection,
        dense_range: (0.0, 1.0),
        seed: 0xEB5,
    })
}

#[test]
fn local_eb_fault_journals_one_escalated_event() {
    let mut model = eb_model(1, Protection::DetectRecompute);
    model.events = EventSink::with_capacity(16);
    let sink = model.events.clone();
    let mut rng = Pcg32::new(1);
    let reqs = model.synth_requests(1, &mut rng);
    // Corrupt a code the single request's bag actually reads: high bit
    // of the first touched row's first code — Δ = α·128 against a 1e-5
    // relative bound, far past the EB significance margin.
    let victim = reqs[0].sparse[0][0];
    model.tables[0].data[victim * model.cfg.embedding_dim] ^= 0x80;
    let (_, rep) = model.forward(&reqs);
    assert_eq!(rep.eb_bags_flagged, 1);
    assert_eq!(rep.eb_bags_unrecovered, 1, "memory corruption survives the re-gather");
    let j = sink.journal().unwrap();
    assert_eq!(j.total(), 1, "one fault, one event");
    let ev = j.recent(1)[0];
    assert_eq!(ev.site, SiteId::Eb(0));
    assert_eq!(ev.unit, UnitRef::Bag { request: 0, replica: LOCAL_REPLICA });
    assert_eq!(ev.detector, Detector::EbBound);
    assert_eq!(ev.severity, Severity::Significant);
    assert_eq!(ev.resolution, Resolution::Escalated(Recovery::RetryBatch));
}

#[test]
fn shard_router_fault_journals_failover_event_and_serves_clean() {
    let mut model = eb_model(2, Protection::DetectRecompute);
    model.events = EventSink::with_capacity(64);
    let sink = model.events.clone();
    let plan = ShardPlan::hash_placement(2, 1, 2);
    let store = Arc::new(ShardStore::from_model(&model, plan, 120));
    let router = ShardRouter::new(Arc::clone(&store));
    let mut rng = Pcg32::new(2);
    let reqs = model.synth_requests(1, &mut rng);
    let (clean, _) = model.forward(&reqs);
    assert_eq!(sink.journal().unwrap().total(), 0, "clean forward journals nothing");
    // Smash every row of table 0 in replica 0: the bag detects
    // persistently, the shard fails over to replica 1.
    let d = model.cfg.embedding_dim;
    for row in 0..model.tables[0].rows {
        store.flip_table_byte(0, 0, row * d, 0x80);
    }
    let (got, rep) = model.forward_with(&reqs, &router);
    assert_eq!(got, clean, "failover serves the clean value");
    assert!(rep.clean());
    let j = sink.journal().unwrap();
    assert_eq!(j.total(), 1, "one persistent bag, one event");
    let ev = j.recent(1)[0];
    assert_eq!(ev.site, SiteId::Eb(0));
    assert_eq!(ev.unit, UnitRef::Bag { request: 0, replica: 0 });
    assert_eq!(ev.detector, Detector::EbBound);
    assert_eq!(ev.severity, Severity::Significant);
    assert_eq!(ev.resolution, Resolution::Recovered(Recovery::FailoverReplica));
}

#[test]
fn r1_router_fault_journals_degraded_event() {
    let mut model = eb_model(1, Protection::DetectRecompute);
    model.events = EventSink::with_capacity(16);
    let sink = model.events.clone();
    let store = Arc::new(ShardStore::from_model(&model, ShardPlan::hash_placement(1, 1, 1), 120));
    let router = ShardRouter::new(Arc::clone(&store));
    let mut rng = Pcg32::new(3);
    let reqs = model.synth_requests(1, &mut rng);
    let d = model.cfg.embedding_dim;
    for row in 0..model.tables[0].rows {
        store.flip_table_byte(0, 0, row * d, 0x80);
    }
    let (_, rep) = model.forward_with(&reqs, &router);
    assert!(rep.eb_bags_unrecovered > 0);
    let ev = sink.journal().unwrap().recent(1)[0];
    assert_eq!(ev.resolution, Resolution::Degraded, "R=1 exhausts the ladder — never silent");
    assert_eq!(ev.site, SiteId::Eb(0));
}

#[test]
fn scrub_hits_journal_self_heal_quarantine_and_local_report_events() {
    // Sharded: a low-bit flip (Δ = 1, below the Table-III significance
    // split) in a replica → ScrubExact event that self-heals in place —
    // the dual checksum names the slot, the algebraic rewrite
    // re-verifies, and the replica is never quarantined (PR 6).
    let mut model = eb_model(2, Protection::DetectRecompute);
    model.events = EventSink::with_capacity(16);
    let sink = model.events.clone();
    let store = Arc::new(ShardStore::from_model(&model, ShardPlan::hash_placement(2, 1, 2), 120));
    let reference = store.table_bytes(1, 1);
    store.flip_table_byte(1, 1, 5 * model.cfg.embedding_dim + 2, 0x01);
    assert_eq!(store.scrub_full(), 1);
    let j = sink.journal().unwrap();
    assert_eq!(j.total(), 1);
    let ev = j.recent(1)[0];
    assert_eq!(ev.site, SiteId::Eb(1));
    assert_eq!(ev.unit, UnitRef::ScrubSlot { replica: 1, row: 5 });
    assert_eq!(ev.detector, Detector::ScrubExact);
    assert_eq!(ev.severity, Severity::NearBound, "Δ=1 is below the significance split");
    assert_eq!(ev.resolution, Resolution::Recovered(Recovery::CorrectInPlace));
    assert_eq!(store.quarantined_replicas(), 0, "healed in place, not quarantined");
    assert_eq!(store.table_bytes(1, 1), reference, "heal restores the exact bytes");
    assert_eq!(store.stats.self_heals.load(std::sync::atomic::Ordering::Relaxed), 1);

    // A sum-preserving pair (+1/−1 in one row) defeats single-slot
    // localization — the same scrub site must fall down the ladder to
    // quarantine + repair instead of guessing a rewrite.
    let d = model.cfg.embedding_dim;
    let bytes = store.table_bytes(1, 1);
    let idx = (0..bytes.len())
        .step_by(d)
        .find(|&i| bytes[i] <= 254 && bytes[i + 1] >= 1)
        .expect("some row admits a ±1 pair");
    store.flip_table_byte(1, 1, idx, bytes[idx] ^ (bytes[idx] + 1));
    store.flip_table_byte(1, 1, idx + 1, bytes[idx + 1] ^ (bytes[idx + 1] - 1));
    assert_eq!(store.scrub_full(), 1);
    let ev = j.recent(1)[0];
    assert_eq!(ev.detector, Detector::ScrubExact);
    assert_eq!(ev.unit, UnitRef::ScrubSlot { replica: 1, row: (idx / d) as u32 });
    // Escalated, not Recovered: the repair is queued, not yet proven.
    assert_eq!(ev.resolution, Resolution::Escalated(Recovery::QuarantineAndRepair));
    assert_eq!(store.quarantined_replicas(), 1, "unlocalizable corruption quarantines");

    // Local (unsharded) scrubber: the engine's own tables have no
    // replica — the ladder is empty and the event is report-only.
    let engine = Engine::new(eb_model(1, Protection::DetectRecompute)).with_scrubbing(1000);
    {
        let mut m = engine.model.write().unwrap();
        let d = m.cfg.embedding_dim;
        m.tables[0].data[7 * d] ^= 0x80; // high bit: significant
    }
    let tick = engine.scrub_tick();
    assert_eq!(tick.hits, vec![(0, 7)]);
    let j = engine.journal();
    assert_eq!(j.total(), 1);
    let ev = j.recent(1)[0];
    assert_eq!(ev.site, SiteId::Eb(0));
    assert_eq!(ev.unit, UnitRef::ScrubSlot { replica: LOCAL_REPLICA, row: 7 });
    assert_eq!(ev.severity, Severity::Significant);
    assert_eq!(ev.resolution, Resolution::DetectedOnly);
    assert_eq!(
        engine.metrics.scrub_hits.load(std::sync::atomic::Ordering::Relaxed),
        1,
        "the sink routes scrub events into the scrub_hits counter"
    );
}

#[test]
fn engine_retry_trail_and_snapshot_counts() {
    // Persistent local EB corruption through the engine: the batch
    // detects, retries (the RetryBatch rung re-reads the same bad
    // memory), and degrades — the journal records the detection from
    // BOTH passes, and the metrics snapshot embeds the counts.
    let mut rng = Pcg32::new(4);
    let engine = Engine::new(eb_model(1, Protection::DetectRecompute));
    let (reqs, victim) = {
        let model = engine.model.read().unwrap();
        let reqs = model.synth_requests(1, &mut rng);
        (reqs.clone(), reqs[0].sparse[0][0])
    };
    {
        let mut model = engine.model.write().unwrap();
        let d = model.cfg.embedding_dim;
        model.tables[0].data[victim * d] ^= 0x80;
    }
    let mut scores = vec![0f32; 1];
    let outcome = engine.score(&reqs, &mut scores);
    assert!(outcome.detected && outcome.recomputed && outcome.degraded);
    let j = engine.journal();
    assert_eq!(j.total(), 2, "one detection event per forward pass");
    for ev in j.recent(2) {
        assert_eq!(ev.site, SiteId::Eb(0));
        assert_eq!(ev.resolution, Resolution::Escalated(Recovery::RetryBatch));
        assert_eq!(ev.tick, 1, "both events stamp the batch's journal tick");
    }
    let snap = engine.metrics_snapshot();
    assert_eq!(snap.path(&["events", "total"]).and_then(Json::as_usize), Some(2));
    assert_eq!(
        snap.path(&["events", "by_detector", "eb_bound"]).and_then(Json::as_usize),
        Some(2)
    );
    assert_eq!(
        snap.path(&["events", "by_resolution", "escalated"]).and_then(Json::as_usize),
        Some(2)
    );
    assert_eq!(snap.get("detections").and_then(Json::as_usize), Some(2));
}

#[test]
fn journal_wraps_without_losing_aggregate_truth() {
    // Capacity-4 sink under repeated faults: the ring keeps the newest 4
    // events, the aggregates keep the lifetime truth.
    let mut model = eb_model(1, Protection::DetectRecompute);
    model.events = EventSink::with_capacity(4);
    let sink = model.events.clone();
    let mut rng = Pcg32::new(5);
    let reqs = model.synth_requests(1, &mut rng);
    let victim = reqs[0].sparse[0][0];
    model.tables[0].data[victim * model.cfg.embedding_dim] ^= 0x80;
    for _ in 0..6 {
        model.forward(&reqs);
    }
    let j = sink.journal().unwrap();
    assert_eq!(j.total(), 6);
    assert_eq!(j.len(), 4);
    assert_eq!(j.dropped(), 2);
    assert_eq!(j.recent(16).len(), 4, "only the resident tail is readable");
    let c = j.counts_json();
    assert_eq!(c.path(&["by_detector", "eb_bound"]).and_then(Json::as_usize), Some(6));
    assert_eq!(c.path(&["by_severity", "significant"]).and_then(Json::as_usize), Some(6));
}

#[test]
fn ladder_shape_matches_the_site_flows() {
    // The declarative ladder the sites consult — one global order,
    // per-class applicability (the five-site surgery this PR removes).
    assert_eq!(
        recovery::ladder(SiteClass::EbSharded),
        [
            Recovery::RecomputeUnit,
            Recovery::FailoverReplica,
            Recovery::QuarantineAndRepair,
            Recovery::Degrade
        ]
        .as_slice()
    );
    assert_eq!(
        recovery::ladder(SiteClass::GemmRow),
        [
            Recovery::CorrectInPlace,
            Recovery::RecomputeUnit,
            Recovery::RetryBatch,
            Recovery::Degrade
        ]
        .as_slice()
    );
    assert_eq!(
        recovery::ladder(SiteClass::ScrubSharded),
        [Recovery::CorrectInPlace, Recovery::QuarantineAndRepair].as_slice()
    );
    assert_eq!(recovery::first_step(SiteClass::GemmRow), Some(Recovery::CorrectInPlace));
    assert_eq!(recovery::first_step(SiteClass::GemmAggregate), Some(Recovery::RetryBatch));
    assert_eq!(recovery::first_step(SiteClass::ScrubLocal), None);
}
