//! Integration: the full serving stack — TCP server, dynamic batcher,
//! engine with ABFT policy — under clean traffic and under chaos.

use dlrm_abft::coordinator::{
    BatchPolicy, ChaosConfig, Client, Engine, ScoreRequest, Server,
};
use dlrm_abft::dlrm::{DlrmConfig, DlrmModel, Protection, TableConfig};
use dlrm_abft::policy::PolicyConfig;
use dlrm_abft::util::json::Json;
use dlrm_abft::util::rng::Pcg32;
use std::sync::Arc;
use std::time::Duration;

fn cfg(protection: Protection) -> DlrmConfig {
    DlrmConfig {
        num_dense: 6,
        embedding_dim: 16,
        bottom_mlp: vec![32, 16],
        top_mlp: vec![32],
        tables: vec![
            TableConfig { rows: 2_000, pooling: 10 },
            TableConfig { rows: 1_000, pooling: 5 },
        ],
        protection,
        dense_range: (0.0, 1.0),
        seed: 21,
    }
}

fn requests(model: &DlrmModel, n: usize, seed: u64) -> Vec<ScoreRequest> {
    let mut rng = Pcg32::new(seed);
    model
        .synth_requests(n, &mut rng)
        .into_iter()
        .enumerate()
        .map(|(i, r)| ScoreRequest { id: i as u64, dense: r.dense, sparse: r.sparse })
        .collect()
}

fn policy() -> BatchPolicy {
    BatchPolicy {
        max_batch: 8,
        max_wait: Duration::from_millis(1),
        max_queue: 256,
        loops: 1,
    }
}

#[test]
fn clean_traffic_end_to_end() {
    let model = DlrmModel::random(cfg(Protection::DetectRecompute));
    let reqs = requests(&model, 20, 1);
    let engine = Arc::new(Engine::new(model));
    let server = Server::start("127.0.0.1:0", Arc::clone(&engine), policy()).unwrap();
    let mut client = Client::connect(&server.addr).unwrap();
    for req in &reqs {
        let resp = client.score(req).unwrap();
        assert_eq!(resp.id, req.id);
        assert!((0.0..=1.0).contains(&resp.score));
        assert!(!resp.detected);
    }
    let m = client.metrics().unwrap();
    assert_eq!(m.get("requests").and_then(Json::as_usize), Some(20));
    assert_eq!(m.get("detections").and_then(Json::as_usize), Some(0));
    server.stop();
}

#[test]
fn chaos_traffic_detected_recovered_and_scores_match_clean() {
    // Serve the same requests through a clean engine and a chaos engine:
    // every response must match (transient faults repaired before reply).
    let clean_model = DlrmModel::random(cfg(Protection::DetectRecompute));
    let reqs = requests(&clean_model, 12, 2);
    let clean_engine = Engine::new(clean_model);
    let clean_scores: Vec<f32> = clean_engine
        .process_batch(reqs.clone())
        .into_iter()
        .map(|r| r.score)
        .collect();

    let chaos_engine = Arc::new(Engine::with_chaos(
        DlrmModel::random(cfg(Protection::DetectRecompute)),
        ChaosConfig { p_weight_flip: 1.0, p_table_flip: 0.0, seed: 5 },
    ));
    let server = Server::start("127.0.0.1:0", Arc::clone(&chaos_engine), policy()).unwrap();
    let mut client = Client::connect(&server.addr).unwrap();
    let mut any_detected = false;
    let mut mismatches = 0usize;
    let mut total = 0usize;
    for _round in 0..5 {
        for (req, &clean) in reqs.iter().zip(&clean_scores) {
            let resp = client.score(req).unwrap();
            total += 1;
            if resp.score != clean {
                // ABFT's guarantee is probabilistic (~95% for B errors,
                // §IV-C): a flip whose row-sum delta ≡ 0 (mod 127) can
                // escape and alter a score. It must stay rare.
                mismatches += 1;
            } else if resp.detected {
                assert!(!resp.degraded, "transient fault must recover");
            }
            any_detected |= resp.detected;
        }
    }
    assert!(any_detected, "p=1.0 weight chaos never detected");
    assert!(
        mismatches * 10 < total,
        "undetected-escape rate too high: {mismatches}/{total}"
    );
    let det = chaos_engine
        .metrics
        .detections
        .load(std::sync::atomic::Ordering::Relaxed);
    assert!(det > 0);
    server.stop();
}

#[test]
fn unprotected_engine_under_chaos_shows_why_abft_matters() {
    // The negative control: with Protection::Off the chaos flips go
    // unnoticed — detections stay zero even though outputs may be wrong.
    let engine = Arc::new(Engine::with_chaos(
        DlrmModel::random(cfg(Protection::Off)),
        ChaosConfig { p_weight_flip: 1.0, p_table_flip: 0.5, seed: 9 },
    ));
    let model_for_reqs = DlrmModel::random(cfg(Protection::Off));
    let reqs = requests(&model_for_reqs, 10, 3);
    let resps = engine.process_batch(reqs);
    assert!(resps.iter().all(|r| !r.detected));
    assert_eq!(
        engine.metrics.detections.load(std::sync::atomic::Ordering::Relaxed),
        0
    );
}

#[test]
fn policy_metrics_flow_through_the_server_metrics_op() {
    // Policy-enabled engine behind the TCP front-end: scores are served
    // normally, and the metrics op carries the policy counters + block.
    let model = DlrmModel::random(cfg(Protection::DetectRecompute));
    let reqs = requests(&model, 8, 7);
    let clean: Vec<f32> = Engine::new(DlrmModel::random(cfg(Protection::DetectRecompute)))
        .process_batch(reqs.clone())
        .into_iter()
        .map(|r| r.score)
        .collect();
    let engine = Arc::new(
        Engine::new(model).with_policy(PolicyConfig {
            cooldown_ticks: 1,
            decay_patience: 1,
            ..PolicyConfig::default()
        }),
    );
    let server = Server::start("127.0.0.1:0", Arc::clone(&engine), policy()).unwrap();
    let mut client = Client::connect(&server.addr).unwrap();
    for (req, want) in reqs.iter().zip(&clean) {
        let resp = client.score(req).unwrap();
        assert_eq!(resp.score, *want, "policy must not move clean scores");
        assert!(!resp.detected);
    }
    // Quiet ticks decay sites toward the budget target.
    for _ in 0..4 {
        engine.policy_tick().expect("policy attached");
    }
    let m = client.metrics().unwrap();
    assert_eq!(m.get("requests").and_then(Json::as_usize), Some(8));
    assert!(m.get("policy_escalations").is_some(), "flat escalation counter");
    assert!(
        m.get("policy_decays").and_then(Json::as_usize).unwrap_or(0) > 0,
        "quiet ticks must have decayed at least one site: {m}"
    );
    let served_full = m
        .path(&["policy", "served", "full"])
        .and_then(Json::as_usize)
        .expect("per-mode served counters in the policy block");
    assert!(served_full > 0, "traffic before decay served under Full");
    assert!(m.path(&["policy", "sites"]).is_some());
    server.stop();
}

#[test]
fn backpressure_overload_reports_error() {
    let model = DlrmModel::random(cfg(Protection::Detect));
    let engine = Arc::new(Engine::new(model));
    let tight = BatchPolicy {
        max_batch: 2,
        max_wait: Duration::from_millis(50),
        max_queue: 1,
        loops: 1,
    };
    let server = Server::start("127.0.0.1:0", engine, tight).unwrap();
    // Flood from several threads; at least everything terminates and the
    // server stays alive (responses are either scores or "overloaded").
    let model2 = DlrmModel::random(cfg(Protection::Detect));
    let reqs = requests(&model2, 8, 4);
    let addr = server.addr;
    let handles: Vec<_> = reqs
        .into_iter()
        .map(|req| {
            std::thread::spawn(move || {
                let mut c = Client::connect(&addr).unwrap();
                c.score(&req).is_ok()
            })
        })
        .collect();
    let mut oks = 0;
    for h in handles {
        if h.join().unwrap() {
            oks += 1;
        }
    }
    assert!(oks >= 1, "at least some requests must be served");
    server.stop();
}
