//! Integration tests for the sharded replicated model store:
//! sharded-vs-unsharded bit-exact equivalence over an N×R grid (including
//! the N=1/R=1 degenerate corners) and the full failover drill —
//! inject → detect → quarantine → serve from replica → repair → re-admit.

use dlrm_abft::coordinator::Engine;
use dlrm_abft::dlrm::{DlrmConfig, DlrmModel, DlrmRequest, Protection, TableConfig};
use dlrm_abft::shard::{RepairWorker, ReplicaState, ShardPlan, ShardRouter, ShardStore};
use dlrm_abft::util::json::Json;
use dlrm_abft::util::rng::Pcg32;
use std::sync::atomic::Ordering;
use std::sync::Arc;

fn model(protection: Protection, seed: u64) -> DlrmModel {
    DlrmModel::random(DlrmConfig {
        num_dense: 6,
        embedding_dim: 16,
        bottom_mlp: vec![32, 16],
        top_mlp: vec![32],
        tables: vec![
            TableConfig { rows: 300, pooling: 6 },
            TableConfig { rows: 200, pooling: 4 },
            TableConfig { rows: 150, pooling: 3 },
            TableConfig { rows: 100, pooling: 5 },
        ],
        protection,
        dense_range: (0.0, 1.0),
        seed,
    })
}

fn requests(m: &DlrmModel, n: usize, seed: u64) -> Vec<DlrmRequest> {
    let mut rng = Pcg32::new(seed);
    m.synth_requests(n, &mut rng)
}

fn router(m: &DlrmModel, n: usize, r: usize) -> (Arc<ShardStore>, ShardRouter) {
    let plan = ShardPlan::hash_placement(m.tables.len(), n, r);
    let store = Arc::new(ShardStore::from_model(m, plan, 64));
    (Arc::clone(&store), ShardRouter::new(store))
}

/// Smash the high bit of every row's first code of `table` in `replica`,
/// so any bag over the table detects persistently on that replica.
fn smash_table(store: &ShardStore, m: &DlrmModel, table: usize, replica: usize) -> usize {
    let d = m.cfg.embedding_dim;
    let mut shard = 0;
    for row in 0..m.tables[table].rows {
        shard = store.flip_table_byte(table, replica, row * d, 0x80);
    }
    shard
}

#[test]
fn sharded_equals_unsharded_over_nxr_grid() {
    for &protection in &[Protection::DetectRecompute, Protection::Detect, Protection::Off] {
        let m = model(protection, 0xA1);
        let reqs = requests(&m, 7, 1);
        let (want, wrep) = m.forward(&reqs);
        assert!(wrep.clean() || !protection.enabled());
        // Grid includes both degenerate corners (N=1/R=1), N == tables,
        // and N > tables (empty shards).
        for n in [1usize, 2, 3, 4, 9] {
            for r in [1usize, 2, 3] {
                let (_store, router) = router(&m, n, r);
                let (got, rep) = m.forward_with(&reqs, &router);
                assert_eq!(got, want, "N={n} R={r} {protection:?}");
                assert_eq!(rep.shard_detections, 0, "clean store must not flag");
                assert_eq!(rep.shard_failovers, 0);
            }
        }
    }
}

#[test]
fn parallel_shard_fanout_bit_identical_to_serial_paths() {
    // Large enough batch×pooling×d to cross EB_PAR_MIN_WORK, so both the
    // local request-parallel stage and the router's per-shard fan-out
    // take their threadpool paths — results must still be bit-identical.
    let m = DlrmModel::random(DlrmConfig {
        num_dense: 6,
        embedding_dim: 16,
        bottom_mlp: vec![32, 16],
        top_mlp: vec![32],
        tables: vec![TableConfig { rows: 400, pooling: 30 }; 4],
        protection: Protection::DetectRecompute,
        dense_range: (0.0, 1.0),
        seed: 0x77,
    });
    let batch = 80;
    let reqs = requests(&m, batch, 9);
    let eb_work: usize = reqs
        .iter()
        .flat_map(|r| r.sparse.iter())
        .map(|s| s.len() * m.cfg.embedding_dim)
        .sum();
    assert!(eb_work >= 1 << 17, "test must cross the fan-out gate ({eb_work})");
    let (want, _) = m.forward(&reqs);
    for n in [2usize, 4] {
        let (_store, router) = router(&m, n, 2);
        let (got, rep) = m.forward_with(&reqs, &router);
        assert_eq!(got, want, "N={n}");
        assert!(rep.clean());
    }
}

#[test]
fn failover_drill_inject_detect_quarantine_serve_repair_readmit() {
    let m = model(Protection::DetectRecompute, 0xB2);
    let reqs = requests(&m, 6, 2);
    let (clean, _) = m.forward(&reqs);
    let (store, router) = router(&m, 2, 2);

    // Inject: persistent corruption in replica 0 of table 1.
    let shard = smash_table(&store, &m, 1, 0);
    let slot = store.plan.slot_of(1).1;

    // Detect + quarantine + failover: the corrupted value never reaches
    // the response, and the batch is not even marked dirty.
    let (got, rep) = m.forward_with(&reqs, &router);
    assert_eq!(got, clean, "detected corruption must never be served");
    assert!(rep.clean());
    assert!(rep.shard_detections >= 1);
    assert_eq!(rep.shard_quarantines, 1);
    assert!(rep.shard_failovers >= 1);
    assert_eq!(store.replica_state(shard, 0), ReplicaState::Quarantined);
    assert_eq!(store.replica_state(shard, 1), ReplicaState::Healthy);

    // Traffic continues during the outage — zero downtime, no new events.
    for trial in 0..3 {
        let (got2, rep2) = m.forward_with(&reqs, &router);
        assert_eq!(got2, clean, "trial {trial}");
        assert_eq!(rep2.shard_detections, 0);
        assert_eq!(rep2.shard_quarantines, 0);
    }

    // Repair: re-copy from the clean replica, checksum-verified, re-admit.
    assert!(store.pending_repairs() >= 1);
    assert!(store.drain_repairs() >= 1);
    assert_eq!(store.replica_state(shard, 0), ReplicaState::Healthy);
    assert_eq!(
        store.read_replica(shard, 0).tables[slot].data,
        m.tables[1].data,
        "repaired replica must be byte-identical to the pristine table"
    );
    assert_eq!(store.stats.repairs.load(Ordering::Relaxed), 1);

    // Re-admitted replica serves cleanly again.
    let (got3, rep3) = m.forward_with(&reqs, &router);
    assert_eq!(got3, clean);
    assert_eq!(rep3.shard_detections, 0);
    assert_eq!(store.quarantined_replicas(), 0);
}

#[test]
fn degenerate_r1_has_no_failover_target_and_degrades() {
    let m = model(Protection::DetectRecompute, 0xC3);
    let reqs = requests(&m, 4, 3);
    let (store, router) = router(&m, 1, 1);
    smash_table(&store, &m, 0, 0);
    let (_, rep) = m.forward_with(&reqs, &router);
    assert!(rep.eb_bags_flagged > 0, "R=1 must surface the corruption");
    assert!(rep.eb_bags_unrecovered > 0);
    assert!(!rep.clean());
    // Repair cannot find a clean source; the replica stays quarantined.
    store.drain_repairs();
    assert_eq!(store.quarantined_replicas(), 1);
    assert!(store.stats.failed_repairs.load(Ordering::Relaxed) >= 1);
}

#[test]
fn scrub_catches_cold_corruption_self_heal_then_quarantine() {
    let m = model(Protection::DetectRecompute, 0xD4);
    let (store, router) = router(&m, 2, 2);
    // One low-bit flip in one cold row of replica 1: under the float
    // bound and likely untouched — the request path can miss it, the
    // exact integer scrubber cannot. Since PR 6 the dual checksum
    // localizes the single corrupt slot, so the scrubber self-heals in
    // place instead of quarantining: the replica never leaves service
    // and no repair copy is needed.
    let d = m.cfg.embedding_dim;
    let victim_row = m.tables[2].rows - 1;
    let shard = store.flip_table_byte(2, 1, victim_row * d + 3, 0x01);
    let mut hits = Vec::new();
    for _ in 0..(m.tables[2].rows / 64 + 2) * 4 {
        hits.extend(store.scrub_tick().1);
        if !hits.is_empty() {
            break;
        }
    }
    assert_eq!(hits.len(), 1);
    let (s, r, t, row) = hits[0];
    assert_eq!((s, r, t, row), (shard, 1, 2, victim_row));
    assert_eq!(store.replica_state(shard, 1), ReplicaState::Healthy);
    assert_eq!(store.table_bytes(2, 1), m.tables[2].data, "heal must restore bytes");
    assert!(store.stats.self_heals.load(Ordering::Relaxed) >= 1);
    assert_eq!(store.pending_repairs(), 0, "self-heal needs no repair copy");
    // Serving was never interrupted and still matches the unsharded path.
    let reqs = requests(&m, 4, 4);
    let (want, _) = m.forward(&reqs);
    let (got, rep) = m.forward_with(&reqs, &router);
    assert_eq!(got, want);
    assert!(rep.clean());

    // A sum-preserving pair (+1/-1 in one row) defeats localization —
    // the scrubber falls back to quarantine + repair as before PR 6.
    let bytes = store.table_bytes(2, 1);
    let idx = (0..bytes.len())
        .step_by(d)
        .find(|&i| bytes[i] <= 254 && bytes[i + 1] >= 1)
        .expect("some row admits a +1/-1 pair");
    store.flip_table_byte(2, 1, idx, bytes[idx] ^ (bytes[idx] + 1));
    store.flip_table_byte(2, 1, idx + 1, bytes[idx + 1] ^ (bytes[idx + 1] - 1));
    let mut hits = Vec::new();
    for _ in 0..(m.tables[2].rows / 64 + 2) * 4 {
        hits.extend(store.scrub_tick().1);
        if !hits.is_empty() {
            break;
        }
    }
    assert_eq!(hits.len(), 1);
    assert_eq!(hits[0], (shard, 1, 2, idx / d));
    assert_eq!(store.replica_state(shard, 1), ReplicaState::Quarantined);
    // Repair re-admits with pristine bytes.
    store.drain_repairs();
    assert_eq!(store.replica_state(shard, 1), ReplicaState::Healthy);
    assert_eq!(store.table_bytes(2, 1), m.tables[2].data);
}

#[test]
fn background_repair_worker_readmits_while_serving() {
    let m = model(Protection::DetectRecompute, 0xE5);
    let reqs = requests(&m, 5, 5);
    let (clean, _) = m.forward(&reqs);
    let (store, router) = router(&m, 2, 2);
    let worker = RepairWorker::spawn(Arc::clone(&store));

    let shard = smash_table(&store, &m, 3, 0);
    let (got, rep) = m.forward_with(&reqs, &router);
    assert_eq!(got, clean);
    assert_eq!(rep.shard_quarantines, 1);

    // The worker repairs in the background while traffic keeps flowing.
    let mut healthy = false;
    for _ in 0..500 {
        let (got2, _) = m.forward_with(&reqs, &router);
        assert_eq!(got2, clean, "traffic must stay correct during repair");
        if store.replica_state(shard, 0) == ReplicaState::Healthy {
            healthy = true;
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(2));
    }
    assert!(healthy, "worker never re-admitted the replica");
    assert_eq!(store.table_bytes(3, 0), m.tables[3].data);
    drop(worker);
}

#[test]
fn sharded_engine_end_to_end_with_metrics() {
    use dlrm_abft::coordinator::ScoreRequest;
    let m = model(Protection::DetectRecompute, 0xF6);
    let score_reqs: Vec<ScoreRequest> = requests(&m, 6, 6)
        .into_iter()
        .enumerate()
        .map(|(i, r)| ScoreRequest { id: i as u64, dense: r.dense, sparse: r.sparse })
        .collect();
    let plain = Engine::new(model(Protection::DetectRecompute, 0xF6));
    let sharded = Engine::new(m)
        .with_shards(ShardPlan::hash_placement(4, 2, 2), 64)
        .with_repair_worker();
    let want = plain.process_batch(score_reqs.clone());
    let got = sharded.process_batch(score_reqs.clone());
    for (w, g) in want.iter().zip(&got) {
        assert_eq!(w.score, g.score);
    }

    // Corrupt a replica through the store handle, serve, and watch the
    // health surface through the metrics snapshot.
    let store = Arc::clone(sharded.shard_store().unwrap());
    let d = {
        let guard = sharded.model.read().unwrap();
        guard.cfg.embedding_dim
    };
    let rows = {
        let guard = sharded.model.read().unwrap();
        guard.tables[0].rows
    };
    for row in 0..rows {
        store.flip_table_byte(0, 0, row * d, 0x80);
    }
    let got2 = sharded.process_batch(score_reqs);
    for (w, g) in want.iter().zip(&got2) {
        assert_eq!(w.score, g.score, "failover must preserve scores");
        assert!(!g.detected && !g.degraded);
    }
    assert!(sharded.metrics.shard_detections.load(Ordering::Relaxed) >= 1);
    assert_eq!(sharded.metrics.shard_quarantines.load(Ordering::Relaxed), 1);
    let snap = sharded.metrics_snapshot();
    let shards_block = snap.get("shards").expect("sharded snapshot has health");
    assert!(shards_block.get("quarantines").and_then(Json::as_usize).unwrap_or(0) >= 1);
}
