//! Integration: the PJRT runtime against the AOT artifacts — the rust
//! native operators and the jax/Pallas-lowered computations must agree
//! bit-for-bit on integers. Skips (with a loud message) if `make
//! artifacts` has not run.

use dlrm_abft::abft::AbftGemm;
use dlrm_abft::runtime::{PjrtEngine, Tensor};
use dlrm_abft::util::rng::Pcg32;

// Shapes fixed by python/compile/aot.py.
const M: usize = 16;
const K: usize = 512;
const N: usize = 512;

fn artifacts_dir() -> Option<String> {
    let dir = std::env::var("ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    if std::path::Path::new(&dir).join("abft_gemm.hlo.txt").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: no artifacts at {dir:?} — run `make artifacts`");
        None
    }
}

#[test]
#[ignore = "needs the optional PJRT runtime (add the xla dep, build with --cfg pjrt_runtime) and `make artifacts` outputs"]
fn pallas_artifact_bit_identical_to_native() {
    let Some(dir) = artifacts_dir() else { return };
    let mut engine = PjrtEngine::cpu().unwrap();
    engine.load_hlo_text("abft_gemm", format!("{dir}/abft_gemm.hlo.txt")).unwrap();

    let mut rng = Pcg32::new(0xBEEF);
    let mut a = vec![0u8; M * K];
    let mut b = vec![0i8; K * N];
    rng.fill_u8(&mut a);
    rng.fill_i8(&mut b);
    let native = AbftGemm::new(&b, K, N);
    let (c_native, verdict) = native.exec(&a, M);
    assert!(verdict.clean());

    let out = engine
        .execute(
            "abft_gemm",
            &[
                Tensor::U8(a, vec![M, K]),
                Tensor::I8(native.packed.to_row_major(), vec![K, N + 1]),
            ],
        )
        .unwrap();
    match (&out[0], &out[1]) {
        (Tensor::I32(c, dims), Tensor::I32(res, _)) => {
            assert_eq!(dims, &vec![M, N + 1]);
            assert_eq!(c, &c_native, "Pallas artifact != native kernel");
            assert!(res.iter().all(|&r| r == 0));
        }
        other => panic!("unexpected outputs {other:?}"),
    }
}

#[test]
#[ignore = "needs the optional PJRT runtime (add the xla dep, build with --cfg pjrt_runtime) and `make artifacts` outputs"]
fn pallas_artifact_detects_injected_fault() {
    let Some(dir) = artifacts_dir() else { return };
    let mut engine = PjrtEngine::cpu().unwrap();
    engine.load_hlo_text("abft_gemm", format!("{dir}/abft_gemm.hlo.txt")).unwrap();

    let mut rng = Pcg32::new(0xFACE);
    let mut a = vec![0u8; M * K];
    let mut b = vec![0i8; K * N];
    rng.fill_u8(&mut a);
    rng.fill_i8(&mut b);
    let native = AbftGemm::new(&b, K, N);
    let mut b_enc = native.packed.to_row_major();
    // Flip a payload bit (avoid the checksum column, index n of each row).
    let p = rng.gen_range(0, K);
    let j = rng.gen_range(0, N);
    b_enc[p * (N + 1) + j] = (b_enc[p * (N + 1) + j] as u8 ^ 0x08) as i8;

    let out = engine
        .execute(
            "abft_gemm",
            &[Tensor::U8(a, vec![M, K]), Tensor::I8(b_enc, vec![K, N + 1])],
        )
        .unwrap();
    let Tensor::I32(res, _) = &out[1] else { panic!() };
    let flagged = res.iter().filter(|&&r| r != 0).count();
    assert!(flagged >= M - 2, "only {flagged}/{M} rows flagged");
}

#[test]
#[ignore = "needs the optional PJRT runtime (add the xla dep, build with --cfg pjrt_runtime) and `make artifacts` outputs"]
fn eb_artifact_matches_native_bag() {
    let Some(dir) = artifacts_dir() else { return };
    let mut engine = PjrtEngine::cpu().unwrap();
    engine.load_hlo_text("eb_bag", format!("{dir}/eb_bag.hlo.txt")).unwrap();

    // Shapes fixed by aot.py: rows=10_000, d=64, batch=10, pooling=100.
    let (rows, d, batch, pooling) = (10_000usize, 64usize, 10usize, 100usize);
    let mut rng = Pcg32::new(0xE8);
    let table = dlrm_abft::embedding::QuantTable8::random(rows, d, &mut rng);
    let c_t: Vec<i32> = (0..rows).map(|i| table.code_row_sum(i)).collect();
    let indices: Vec<i32> = (0..batch * pooling)
        .map(|_| rng.gen_range(0, rows) as i32)
        .collect();

    let out = engine
        .execute(
            "eb_bag",
            &[
                Tensor::U8(table.data.clone(), vec![rows, d]),
                Tensor::F32(table.alpha.clone(), vec![rows]),
                Tensor::F32(table.beta.clone(), vec![rows]),
                Tensor::I32(c_t, vec![rows]),
                Tensor::I32(indices.clone(), vec![batch, pooling]),
            ],
        )
        .unwrap();
    let Tensor::F32(result, dims) = &out[0] else { panic!() };
    assert_eq!(dims, &vec![batch, d]);

    // Native bags over the same indices.
    for bagi in 0..batch {
        let idx: Vec<usize> = indices[bagi * pooling..(bagi + 1) * pooling]
            .iter()
            .map(|&i| i as usize)
            .collect();
        let mut native = vec![0f32; d];
        dlrm_abft::embedding::bag_sum_8(&table, &idx, None, false, &mut native);
        for (x, y) in result[bagi * d..(bagi + 1) * d].iter().zip(&native) {
            let tol = 1e-3 * (1.0 + y.abs());
            assert!((x - y).abs() < tol, "bag {bagi}: {x} vs {y}");
        }
    }

    // Fused checksum sides agree with the native policy: clean → no flags.
    let (Tensor::F32(rsum, _), Tensor::F32(csum, _)) = (&out[1], &out[2]) else { panic!() };
    for b in 0..batch {
        let scale = rsum[b].abs().max(csum[b].abs()).max(1.0);
        assert!((rsum[b] - csum[b]).abs() <= 1e-5 * scale, "bag {b} flagged clean");
    }
}

#[test]
#[ignore = "needs the optional PJRT runtime (add the xla dep, build with --cfg pjrt_runtime) and `make artifacts` outputs"]
fn model_artifacts_serve_scores() {
    let Some(dir) = artifacts_dir() else { return };
    let mut engine = PjrtEngine::cpu().unwrap();
    let loaded = engine.load_artifact_dir(&dir).unwrap();
    assert!(loaded.iter().any(|n| n == "model_b1"));
    assert!(loaded.iter().any(|n| n == "model_b8"));

    let mut rng = Pcg32::new(0xD1);
    for (name, batch) in [("model_b1", 1usize), ("model_b8", 8usize)] {
        let dense: Vec<f32> = (0..batch * 8).map(|_| rng.next_f32()).collect();
        let indices: Vec<i32> = (0..batch * 2 * 20)
            .map(|_| rng.gen_range(0, 5000) as i32)
            .collect();
        let out = engine
            .execute(
                name,
                &[
                    Tensor::F32(dense, vec![batch, 8]),
                    Tensor::I32(indices, vec![batch, 2, 20]),
                ],
            )
            .unwrap();
        let Tensor::F32(scores, _) = &out[0] else { panic!() };
        assert_eq!(scores.len(), batch);
        assert!(scores.iter().all(|s| (0.0..=1.0).contains(s)));
        let Tensor::I32(gemm_bad, _) = &out[1] else { panic!() };
        let Tensor::I32(eb_flagged, _) = &out[2] else { panic!() };
        assert_eq!(gemm_bad[0], 0, "{name} clean run flagged GEMM rows");
        assert_eq!(eb_flagged[0], 0, "{name} clean run flagged EB bags");
    }
}
