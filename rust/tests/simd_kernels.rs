//! Property tests for the vectorized hot path: the AVX2 GEMM microkernel,
//! the scalar panel fallback, and the SIMD EmbeddingBag must be
//! **bit-identical** to their reference implementations across a shape
//! sweep that straddles every tiling boundary (NR panels, k pairing,
//! m-row pairing, the ABFT extra column, and the m=1 serving case) — on
//! hosts without AVX2 the dispatch degenerates to scalar and the same
//! assertions hold for the fallback.

use dlrm_abft::abft::{AbftGemm, EbChecksum};
use dlrm_abft::embedding::{bag_sum_8, bag_sum_8_scalar, QuantTable8};
use dlrm_abft::gemm::{gemm_exec, gemm_exec_into, gemm_exec_into_scalar, gemm_naive, PackedB};
use dlrm_abft::util::rng::Pcg32;

fn rand_ab(rng: &mut Pcg32, m: usize, k: usize, n: usize) -> (Vec<u8>, Vec<i8>) {
    let mut a = vec![0u8; m * k];
    let mut b = vec![0i8; k * n];
    rng.fill_u8(&mut a);
    rng.fill_i8(&mut b);
    (a, b)
}

/// The sweep: every (m, k, n) here crosses at least one kernel boundary.
/// NR = 32 (column panel), k pairing = 2, row pairing = 2.
fn boundary_shapes() -> Vec<(usize, usize, usize)> {
    let mut shapes = vec![
        (1, 1, 1),    // degenerate
        (1, 512, 512),   // m=1 serving, aligned
        (1, 511, 513),   // m=1 serving, everything ragged
        (2, 2, 32),      // exactly one panel, one k pair
        (2, 3, 32),      // odd k tail row
        (3, 64, 64),     // odd m tail row
        (4, 128, 31),    // single ragged panel
        (4, 128, 33),    // full panel + width-1 tail panel (ABFT shape)
        (5, 127, 95),    // odd k, ragged panel, odd m
        (7, 129, 160),   // multi-panel, odd everything
        (16, 512, 512),  // DLRM MLP shape
        (17, 256, 257),  // row tail over panel tail
    ];
    // Dense sweep of small shapes around the pairing boundaries.
    for m in [1usize, 2, 3] {
        for k in [1usize, 2, 3, 4, 5] {
            for n in [1usize, 31, 32, 33, 63, 64, 65] {
                shapes.push((m, k, n));
            }
        }
    }
    shapes
}

#[test]
fn gemm_simd_scalar_naive_bit_identical() {
    let mut rng = Pcg32::new(0x51D);
    for (m, k, n) in boundary_shapes() {
        let (a, b) = rand_ab(&mut rng, m, k, n);
        let packed = PackedB::pack(&b, k, n);
        let naive = gemm_naive(&a, &b, m, k, n);
        let dispatched = gemm_exec(&a, &packed, m);
        assert_eq!(dispatched, naive, "dispatch != naive at ({m},{k},{n})");
        let mut scalar = vec![0i32; m * n];
        gemm_exec_into_scalar(&a, &packed, m, &mut scalar);
        assert_eq!(scalar, naive, "scalar != naive at ({m},{k},{n})");
    }
}

#[test]
fn gemm_extra_column_rides_every_shape() {
    // The checksum extra column must behave exactly like an augmented
    // matrix on both kernel paths, across the same boundary sweep.
    let mut rng = Pcg32::new(0xEC);
    for (m, k, n) in boundary_shapes() {
        let (a, b) = rand_ab(&mut rng, m, k, n);
        let mut extra = vec![0i8; k];
        rng.fill_i8(&mut extra);
        let mut b_aug = vec![0i8; k * (n + 1)];
        for p in 0..k {
            b_aug[p * (n + 1)..p * (n + 1) + n].copy_from_slice(&b[p * n..(p + 1) * n]);
            b_aug[p * (n + 1) + n] = extra[p];
        }
        let packed = PackedB::pack_with_extra_col(&b, k, n, &extra);
        let naive = gemm_naive(&a, &b_aug, m, k, n + 1);
        assert_eq!(
            gemm_exec(&a, &packed, m),
            naive,
            "extra-col dispatch at ({m},{k},{n})"
        );
        let mut scalar = vec![0i32; m * (n + 1)];
        gemm_exec_into_scalar(&a, &packed, m, &mut scalar);
        assert_eq!(scalar, naive, "extra-col scalar at ({m},{k},{n})");
    }
}

#[test]
fn gemm_saturation_adversarial_inputs_exact() {
    // Extremes that would saturate a real maddubs (u8=255 × i8=±127/−128):
    // the widened-madd kernel must stay exact.
    for &(m, k, n) in &[(2usize, 64usize, 64usize), (1, 3200, 33), (3, 127, 65)] {
        for (afill, bfill) in [(255u8, 127i8), (255, -128), (255, -127), (128, 127)] {
            let a = vec![afill; m * k];
            let b = vec![bfill; k * n];
            let packed = PackedB::pack(&b, k, n);
            assert_eq!(
                gemm_exec(&a, &packed, m),
                gemm_naive(&a, &b, m, k, n),
                "({m},{k},{n}) a={afill} b={bfill}"
            );
        }
    }
}

#[test]
fn abft_gemm_clean_and_detects_on_simd_path() {
    // The protected GEMM (checksum column packed in) through the
    // dispatched kernel: clean runs verify clean, a payload flip via the
    // panel-layout offset is detected.
    let mut rng = Pcg32::new(0xAB);
    for &(m, k, n) in &[(1usize, 256usize, 256usize), (4, 100, 33), (16, 512, 512)] {
        let (a, b) = rand_ab(&mut rng, m, k, n);
        let mut abft = AbftGemm::new(&b, k, n);
        let (_, verdict) = abft.exec(&a, m);
        assert!(verdict.clean(), "clean ({m},{k},{n})");
        // Flip a high payload bit through the layout-mapping offset.
        let p = rng.gen_range(0, k);
        let j = rng.gen_range(0, n);
        let idx = abft.packed.offset(p, j);
        let old = abft.packed.at(p, j);
        abft.packed.data_mut()[idx] = (old as u8 ^ 0x40) as i8;
        let (_, verdict) = abft.exec(&a, m);
        assert!(!verdict.clean(), "corrupt ({m},{k},{n}) escaped");
    }
}

#[test]
fn eb_simd_bit_identical_and_fused_equals_two_pass() {
    let mut rng = Pcg32::new(0xEB);
    for d in [16usize, 32, 48, 64, 100] {
        let rows = 2000;
        let table = QuantTable8::random(rows, d, &mut rng);
        let cs = EbChecksum::build_8(&table);
        let fused = cs.clone().fuse(&table);
        for trial in 0..10 {
            let pooling = rng.gen_range(1, 120);
            let indices: Vec<usize> = (0..pooling).map(|_| rng.gen_range(0, rows)).collect();
            let weights: Vec<f32> = (0..pooling).map(|_| rng.next_f32() + 0.25).collect();
            let w = if trial % 2 == 0 { None } else { Some(&weights[..]) };

            // SIMD bag == scalar bag, bit for bit.
            let mut simd = vec![0f32; d];
            let mut scalar = vec![0f32; d];
            bag_sum_8(&table, &indices, w, trial % 3 == 0, &mut simd);
            bag_sum_8_scalar(&table, &indices, w, false, &mut scalar);
            assert_eq!(simd, scalar, "d={d} trial={trial}");

            // Fused single-pass checksum == two-pass bag + check_bag:
            // same result vector, same verdict.
            let mut fused_out = vec![0f32; d];
            let flagged = fused.bag_sum_checked(&table, &indices, w, false, &mut fused_out);
            assert_eq!(fused_out, scalar, "fused result d={d} trial={trial}");
            let two_pass = cs.check_bag(&table.alpha, &table.beta, &indices, w, &scalar);
            assert_eq!(flagged, two_pass, "verdict d={d} trial={trial}");
            assert!(!flagged, "clean bag flagged d={d} trial={trial}");
        }
    }
}

#[test]
fn eb_fused_detects_corruption_like_two_pass() {
    let mut rng = Pcg32::new(0xEBB);
    let (rows, d) = (1500usize, 64usize);
    let table = QuantTable8::random(rows, d, &mut rng);
    let cs = EbChecksum::build_8(&table);
    let fused = cs.clone().fuse(&table);
    let indices: Vec<usize> = (0..100).map(|_| rng.gen_range(0, rows)).collect();
    // Corrupt a touched row's high bit after checksums were built.
    let mut bad_table = table.clone();
    bad_table.data[indices[11] * d + 3] ^= 0x80;
    let mut fused_out = vec![0f32; d];
    let fused_flag = fused.bag_sum_checked(&bad_table, &indices, None, false, &mut fused_out);
    let mut plain = vec![0f32; d];
    bag_sum_8(&bad_table, &indices, None, false, &mut plain);
    let two_pass_flag = cs.check_bag(&bad_table.alpha, &bad_table.beta, &indices, None, &plain);
    assert_eq!(fused_out, plain);
    assert_eq!(fused_flag, two_pass_flag);
    assert!(fused_flag, "high-bit table corruption must be flagged");
}

#[test]
fn parallel_gemm_matches_serial_on_large_batch() {
    // Crosses the row-parallel threshold: the fan-out over m blocks must
    // be bit-identical to the single-thread path.
    let mut rng = Pcg32::new(0x9A9);
    let (m, k, n) = (64, 300, 256);
    let (a, b) = rand_ab(&mut rng, m, k, n);
    let packed = PackedB::pack(&b, k, n);
    let mut par = vec![0i32; m * n];
    gemm_exec_into(&a, &packed, m, &mut par);
    let mut ser = vec![0i32; m * n];
    gemm_exec_into_scalar(&a, &packed, m, &mut ser);
    assert_eq!(par, ser);
}
