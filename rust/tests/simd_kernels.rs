//! Property tests for the vectorized hot path: every GEMM kernel tier
//! (scalar, AVX2, int16-accumulation, AVX-512/VNNI), the scalar panel
//! fallback, and the SIMD EmbeddingBag must be **bit-identical** to
//! their reference implementations across a shape sweep that straddles
//! every tiling boundary (NR panels, k pairing, m-row pairing, the ABFT
//! extra column, and the m=1 serving case) — on hosts without the
//! features the dispatch degenerates tier by tier and the same
//! assertions hold for whatever actually runs. The tier-capped grids
//! at the bottom pin each tier explicitly via the dispatch override.

use dlrm_abft::abft::{AbftGemm, EbChecksum};
use dlrm_abft::embedding::{bag_sum_8, bag_sum_8_scalar, QuantTable8};
use dlrm_abft::gemm::{
    gemm_exec, gemm_exec_into, gemm_exec_into_scalar, gemm_naive, select_tier,
    set_kernel_tier_override, simd_active, KernelTier, PackedB,
};
use dlrm_abft::util::rng::Pcg32;
use std::sync::{Mutex, MutexGuard, OnceLock};

/// Serializes tests that set the process-global kernel-tier override.
/// The override is a *cap*, never a force — a concurrent test that
/// doesn't take this lock still computes bit-identical results on
/// whatever tier it lands on — so the lock only keeps the capped grids
/// below from trampling each other's caps.
fn tier_lock() -> MutexGuard<'static, ()> {
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    match LOCK.get_or_init(|| Mutex::new(())).lock() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

/// RAII tier cap: sets the override on construction, always restores
/// "no override" on drop (panic-safe, so one failing grid can't leak a
/// scalar cap into later tests).
struct TierCap(#[allow(dead_code)] MutexGuard<'static, ()>);

impl TierCap {
    fn set(tier: KernelTier) -> Self {
        let guard = tier_lock();
        set_kernel_tier_override(Some(tier));
        TierCap(guard)
    }
}

impl Drop for TierCap {
    fn drop(&mut self) {
        set_kernel_tier_override(None);
    }
}

const ALL_TIERS: [KernelTier; 4] = [
    KernelTier::Scalar,
    KernelTier::Avx2,
    KernelTier::Acc16,
    KernelTier::Avx512,
];

/// Small-magnitude weights (±8) that always earn an acc16 certificate,
/// so the `Acc16` cap actually reaches the int16 kernel on short-k packs.
fn small_weights(rng: &mut Pcg32, len: usize) -> Vec<i8> {
    (0..len).map(|_| (rng.gen_range(0, 17) as i32 - 8) as i8).collect()
}

fn rand_ab(rng: &mut Pcg32, m: usize, k: usize, n: usize) -> (Vec<u8>, Vec<i8>) {
    let mut a = vec![0u8; m * k];
    let mut b = vec![0i8; k * n];
    rng.fill_u8(&mut a);
    rng.fill_i8(&mut b);
    (a, b)
}

/// The sweep: every (m, k, n) here crosses at least one kernel boundary.
/// NR = 32 (column panel), k pairing = 2, row pairing = 2.
fn boundary_shapes() -> Vec<(usize, usize, usize)> {
    let mut shapes = vec![
        (1, 1, 1),    // degenerate
        (1, 512, 512),   // m=1 serving, aligned
        (1, 511, 513),   // m=1 serving, everything ragged
        (2, 2, 32),      // exactly one panel, one k pair
        (2, 3, 32),      // odd k tail row
        (3, 64, 64),     // odd m tail row
        (4, 128, 31),    // single ragged panel
        (4, 128, 33),    // full panel + width-1 tail panel (ABFT shape)
        (5, 127, 95),    // odd k, ragged panel, odd m
        (7, 129, 160),   // multi-panel, odd everything
        (16, 512, 512),  // DLRM MLP shape
        (17, 256, 257),  // row tail over panel tail
    ];
    // Dense sweep of small shapes around the pairing boundaries.
    for m in [1usize, 2, 3] {
        for k in [1usize, 2, 3, 4, 5] {
            for n in [1usize, 31, 32, 33, 63, 64, 65] {
                shapes.push((m, k, n));
            }
        }
    }
    shapes
}

#[test]
fn gemm_simd_scalar_naive_bit_identical() {
    let mut rng = Pcg32::new(0x51D);
    for (m, k, n) in boundary_shapes() {
        let (a, b) = rand_ab(&mut rng, m, k, n);
        let packed = PackedB::pack(&b, k, n);
        let naive = gemm_naive(&a, &b, m, k, n);
        let dispatched = gemm_exec(&a, &packed, m);
        assert_eq!(dispatched, naive, "dispatch != naive at ({m},{k},{n})");
        let mut scalar = vec![0i32; m * n];
        gemm_exec_into_scalar(&a, &packed, m, &mut scalar);
        assert_eq!(scalar, naive, "scalar != naive at ({m},{k},{n})");
    }
}

#[test]
fn gemm_extra_column_rides_every_shape() {
    // The checksum extra column must behave exactly like an augmented
    // matrix on both kernel paths, across the same boundary sweep.
    let mut rng = Pcg32::new(0xEC);
    for (m, k, n) in boundary_shapes() {
        let (a, b) = rand_ab(&mut rng, m, k, n);
        let mut extra = vec![0i8; k];
        rng.fill_i8(&mut extra);
        let mut b_aug = vec![0i8; k * (n + 1)];
        for p in 0..k {
            b_aug[p * (n + 1)..p * (n + 1) + n].copy_from_slice(&b[p * n..(p + 1) * n]);
            b_aug[p * (n + 1) + n] = extra[p];
        }
        let packed = PackedB::pack_with_extra_col(&b, k, n, &extra);
        let naive = gemm_naive(&a, &b_aug, m, k, n + 1);
        assert_eq!(
            gemm_exec(&a, &packed, m),
            naive,
            "extra-col dispatch at ({m},{k},{n})"
        );
        let mut scalar = vec![0i32; m * (n + 1)];
        gemm_exec_into_scalar(&a, &packed, m, &mut scalar);
        assert_eq!(scalar, naive, "extra-col scalar at ({m},{k},{n})");
    }
}

#[test]
fn gemm_saturation_adversarial_inputs_exact() {
    // Extremes that would saturate a real maddubs (u8=255 × i8=±127/−128):
    // the widened-madd kernel must stay exact.
    for &(m, k, n) in &[(2usize, 64usize, 64usize), (1, 3200, 33), (3, 127, 65)] {
        for (afill, bfill) in [(255u8, 127i8), (255, -128), (255, -127), (128, 127)] {
            let a = vec![afill; m * k];
            let b = vec![bfill; k * n];
            let packed = PackedB::pack(&b, k, n);
            assert_eq!(
                gemm_exec(&a, &packed, m),
                gemm_naive(&a, &b, m, k, n),
                "({m},{k},{n}) a={afill} b={bfill}"
            );
        }
    }
}

#[test]
fn abft_gemm_clean_and_detects_on_simd_path() {
    // The protected GEMM (checksum column packed in) through the
    // dispatched kernel: clean runs verify clean, a payload flip via the
    // panel-layout offset is detected.
    let mut rng = Pcg32::new(0xAB);
    for &(m, k, n) in &[(1usize, 256usize, 256usize), (4, 100, 33), (16, 512, 512)] {
        let (a, b) = rand_ab(&mut rng, m, k, n);
        let mut abft = AbftGemm::new(&b, k, n);
        let (_, verdict) = abft.exec(&a, m);
        assert!(verdict.clean(), "clean ({m},{k},{n})");
        // Flip a high payload bit through the layout-mapping offset.
        let p = rng.gen_range(0, k);
        let j = rng.gen_range(0, n);
        let idx = abft.packed.offset(p, j);
        let old = abft.packed.at(p, j);
        abft.packed.data_mut()[idx] = (old as u8 ^ 0x40) as i8;
        let (_, verdict) = abft.exec(&a, m);
        assert!(!verdict.clean(), "corrupt ({m},{k},{n}) escaped");
    }
}

#[test]
fn eb_simd_bit_identical_and_fused_equals_two_pass() {
    let mut rng = Pcg32::new(0xEB);
    for d in [16usize, 32, 48, 64, 100] {
        let rows = 2000;
        let table = QuantTable8::random(rows, d, &mut rng);
        let cs = EbChecksum::build_8(&table);
        let fused = cs.clone().fuse(&table);
        for trial in 0..10 {
            let pooling = rng.gen_range(1, 120);
            let indices: Vec<usize> = (0..pooling).map(|_| rng.gen_range(0, rows)).collect();
            let weights: Vec<f32> = (0..pooling).map(|_| rng.next_f32() + 0.25).collect();
            let w = if trial % 2 == 0 { None } else { Some(&weights[..]) };

            // SIMD bag == scalar bag, bit for bit.
            let mut simd = vec![0f32; d];
            let mut scalar = vec![0f32; d];
            bag_sum_8(&table, &indices, w, trial % 3 == 0, &mut simd);
            bag_sum_8_scalar(&table, &indices, w, false, &mut scalar);
            assert_eq!(simd, scalar, "d={d} trial={trial}");

            // Fused single-pass checksum == two-pass bag + check_bag:
            // same result vector, same verdict.
            let mut fused_out = vec![0f32; d];
            let flagged = fused.bag_sum_checked(&table, &indices, w, false, &mut fused_out);
            assert_eq!(fused_out, scalar, "fused result d={d} trial={trial}");
            let two_pass = cs.check_bag(&table.alpha, &table.beta, &indices, w, &scalar);
            assert_eq!(flagged, two_pass, "verdict d={d} trial={trial}");
            assert!(!flagged, "clean bag flagged d={d} trial={trial}");
        }
    }
}

#[test]
fn eb_fused_detects_corruption_like_two_pass() {
    let mut rng = Pcg32::new(0xEBB);
    let (rows, d) = (1500usize, 64usize);
    let table = QuantTable8::random(rows, d, &mut rng);
    let cs = EbChecksum::build_8(&table);
    let fused = cs.clone().fuse(&table);
    let indices: Vec<usize> = (0..100).map(|_| rng.gen_range(0, rows)).collect();
    // Corrupt a touched row's high bit after checksums were built.
    let mut bad_table = table.clone();
    bad_table.data[indices[11] * d + 3] ^= 0x80;
    let mut fused_out = vec![0f32; d];
    let fused_flag = fused.bag_sum_checked(&bad_table, &indices, None, false, &mut fused_out);
    let mut plain = vec![0f32; d];
    bag_sum_8(&bad_table, &indices, None, false, &mut plain);
    let two_pass_flag = cs.check_bag(&bad_table.alpha, &bad_table.beta, &indices, None, &plain);
    assert_eq!(fused_out, plain);
    assert_eq!(fused_flag, two_pass_flag);
    assert!(fused_flag, "high-bit table corruption must be flagged");
}

#[test]
fn parallel_gemm_matches_serial_on_large_batch() {
    // Crosses the row-parallel threshold: the fan-out over m blocks must
    // be bit-identical to the single-thread path.
    let mut rng = Pcg32::new(0x9A9);
    let (m, k, n) = (64, 300, 256);
    let (a, b) = rand_ab(&mut rng, m, k, n);
    let packed = PackedB::pack(&b, k, n);
    let mut par = vec![0i32; m * n];
    gemm_exec_into(&a, &packed, m, &mut par);
    let mut ser = vec![0i32; m * n];
    gemm_exec_into_scalar(&a, &packed, m, &mut ser);
    assert_eq!(par, ser);
}

// ---------------------------------------------------------------------
// Tier-capped grids (PR 8): pin every dispatch tier explicitly.
// ---------------------------------------------------------------------

#[test]
fn gemm_grid_bit_identical_on_every_tier_cap() {
    // The full boundary battery under each tier cap, with both
    // full-range weights (exercises AVX2/VNNI; acc16 ineligible, falls
    // through) and small-magnitude weights (acc16-certified, so the
    // Acc16 cap genuinely runs the int16 kernel on short-k packs) —
    // plus the ABFT extra column on every shape.
    let mut rng = Pcg32::new(0x7139);
    let shapes: &[(usize, usize, usize)] = &[
        (1, 5, 33),    // m=1 serving, odd k, ragged panel
        (31, 64, 64),  // odd m tail under the pair blocking
        (32, 65, 96),  // even m, odd k
        (33, 63, 32),  // both odd, single panel
        (2, 256, 33),  // acc16 k ceiling, full panel + 1-col tail
        (16, 512, 513), // past the acc16 k ceiling (falls to AVX2)
    ];
    for cap in ALL_TIERS {
        let _cap = TierCap::set(cap);
        for &(m, k, n) in shapes {
            for small in [false, true] {
                let mut a = vec![0u8; m * k];
                rng.fill_u8(&mut a);
                let b = if small {
                    small_weights(&mut rng, k * n)
                } else {
                    let mut b = vec![0i8; k * n];
                    rng.fill_i8(&mut b);
                    b
                };
                let mut extra = vec![0i8; k];
                rng.fill_i8(&mut extra);
                let packed = PackedB::pack_with_extra_col(&b, k, n, &extra);
                let mut b_aug = vec![0i8; k * (n + 1)];
                for p in 0..k {
                    b_aug[p * (n + 1)..p * (n + 1) + n].copy_from_slice(&b[p * n..(p + 1) * n]);
                    b_aug[p * (n + 1) + n] = extra[p];
                }
                let tag = format!("cap={cap:?} ({m},{k},{n}) small={small}");
                // The cap only ever lowers the tier.
                assert!(select_tier(&packed) <= cap, "{tag}: cap must bound the tier");
                if cap == KernelTier::Acc16 && small && k <= 256 && simd_active() {
                    assert!(
                        packed.acc16_proof().is_some(),
                        "{tag}: ±8 weights must certify"
                    );
                    assert_eq!(
                        select_tier(&packed),
                        KernelTier::Acc16,
                        "{tag}: certified short-k pack must reach acc16"
                    );
                }
                assert_eq!(
                    gemm_exec(&a, &packed, m),
                    gemm_naive(&a, &b_aug, m, k, n + 1),
                    "{tag}"
                );
            }
        }
    }
}

#[test]
fn acc16_saturation_certificate_gates_dispatch() {
    // Adversarial i16-saturation battery from max-magnitude operands:
    // (1) a weight pair one past the certifiable line must yield *no*
    // certificate, and the Acc16 cap must fall through to an exact
    // lower tier; (2) the max certifiable operand (|b0|+|b1| = 128,
    // a = 255 everywhere — every pair term ±32640, 127 short of the i16
    // cliff) must certify at spill window 1 and stay bit-exact through
    // dispatch; (3) small weights earn a wide window and stay exact.
    let _cap = TierCap::set(KernelTier::Acc16);

    // (1) |64| + |65| = 129 ⇒ 255·129 = 32895 > 32767: rejected.
    let (m, k, n) = (3usize, 64usize, 64usize);
    let a = vec![255u8; m * k];
    let b: Vec<i8> = (0..k * n)
        .map(|idx| if (idx / n) % 2 == 0 { 65 } else { -64 })
        .collect();
    let packed = PackedB::pack(&b, k, n);
    assert!(
        packed.acc16_proof().is_none(),
        "pair magnitude 129 must not certify"
    );
    assert_ne!(
        select_tier(&packed),
        KernelTier::Acc16,
        "uncertified pack must never dispatch to acc16"
    );
    assert_eq!(gemm_exec(&a, &packed, m), gemm_naive(&a, &b, m, k, n));

    // (2) |b0| + |b1| = 128 ⇒ 255·128 = 32640 ≤ 32767: certifies with
    // the tightest window, and with all-255 activations every pair sum
    // really is ±32640 — 127 short of the i16 cliff, exact only
    // because the window-1 spill fires after every pair block (two
    // same-sign sums would reach 65280 and wrap). Uniform +64 stresses
    // the positive rail; a per-pair-block sign flip stresses both.
    // (Alternating signs *within* a pair would cancel to 0 and test
    // nothing.)
    let (m, k, n) = (4usize, 256usize, 96usize);
    let a = vec![255u8; m * k];
    for flip_blocks in [false, true] {
        let b: Vec<i8> = (0..k * n)
            .map(|idx| {
                let p = idx / n;
                if flip_blocks && (p / 2) % 2 == 1 {
                    -64
                } else {
                    64
                }
            })
            .collect();
        let packed = PackedB::pack(&b, k, n);
        let proof = packed.acc16_proof().expect("boundary operand certifies");
        assert_eq!(proof.spill_pairs, 1, "boundary operand needs spill window 1");
        if simd_active() {
            assert_eq!(select_tier(&packed), KernelTier::Acc16);
        }
        assert_eq!(
            gemm_exec(&a, &packed, m),
            gemm_naive(&a, &b, m, k, n),
            "flip_blocks={flip_blocks}"
        );
    }

    // (3) ±8 weights, max activations, odd k: wide spill window, exact.
    let mut rng = Pcg32::new(0xACCE);
    let (m, k, n) = (5usize, 199usize, 64usize);
    let a = vec![255u8; m * k];
    let b = small_weights(&mut rng, k * n);
    let packed = PackedB::pack(&b, k, n);
    let proof = packed.acc16_proof().expect("±8 weights certify");
    assert!(proof.spill_pairs >= 4, "small weights earn a wide window");
    assert_eq!(gemm_exec(&a, &packed, m), gemm_naive(&a, &b, m, k, n));
}

#[test]
fn abft_verify_and_detect_hold_on_every_tier_cap() {
    // The protected GEMM (checksum + group columns packed in) must
    // verify clean and catch an injected payload flip on every tier —
    // verify/correct read the stored accumulator and the pack's logical
    // offsets, so they are tier-agnostic by construction; this pins it.
    let mut rng = Pcg32::new(0xAB77);
    for cap in ALL_TIERS {
        let _cap = TierCap::set(cap);
        for &(m, k, n, small) in &[
            (4usize, 100usize, 33usize, false),
            (6, 128, 64, true), // acc16-certified under the Acc16 cap
            (16, 512, 512, false),
        ] {
            let mut a = vec![0u8; m * k];
            rng.fill_u8(&mut a);
            let b = if small {
                small_weights(&mut rng, k * n)
            } else {
                let mut b = vec![0i8; k * n];
                rng.fill_i8(&mut b);
                b
            };
            let mut abft = AbftGemm::new(&b, k, n);
            let (_, verdict) = abft.exec(&a, m);
            assert!(verdict.clean(), "cap={cap:?} clean ({m},{k},{n})");
            let p = rng.gen_range(0, k);
            let j = rng.gen_range(0, n);
            let idx = abft.packed.offset(p, j);
            let old = abft.packed.at(p, j);
            abft.packed.data_mut()[idx] = (old as u8 ^ 0x40) as i8;
            let (_, verdict) = abft.exec(&a, m);
            assert!(!verdict.clean(), "cap={cap:?} corrupt ({m},{k},{n}) escaped");
        }
    }
}

#[test]
fn parallel_gemm_matches_serial_on_every_tier_cap() {
    // The row-parallel crossing under each cap: fan-out chunking must
    // compose with every kernel tier bit-identically. Small weights so
    // the Acc16 cap actually runs the int16 kernel (k = 200 ≤ 256).
    let mut rng = Pcg32::new(0x9AA);
    let (m, k, n) = (64usize, 200usize, 256usize);
    let mut a = vec![0u8; m * k];
    rng.fill_u8(&mut a);
    let b = small_weights(&mut rng, k * n);
    let packed = PackedB::pack(&b, k, n);
    assert!(m * k * n >= 1 << 21, "shape must cross GEMM_PAR_MIN_WORK");
    let mut ser = vec![0i32; m * n];
    gemm_exec_into_scalar(&a, &packed, m, &mut ser);
    for cap in ALL_TIERS {
        let _cap = TierCap::set(cap);
        let mut par = vec![0i32; m * n];
        gemm_exec_into(&a, &packed, m, &mut par);
        assert_eq!(par, ser, "cap={cap:?}");
    }
}
