//! Integration: the readiness-driven (epoll) serving front end and the
//! overload-adaptive detection ladder (PR 10).
//!
//! * The async server must be a drop-in: bit-identical scores to the
//!   threaded path, same control ops, same overload reply.
//! * Admission control: a full queue bounces requests with one
//!   `{"error":"overloaded"}` line and recovers as soon as the queue
//!   drains.
//! * The overload drill: under sustained p99 pressure detection steps
//!   down the mode lattice (budgeted sampling, then bound-only) strictly
//!   *before* the controller reaches its shedding state; pressure
//!   clearing unwinds the ladder with hysteresis; an injected fault
//!   escalates its site back to `Full` within one tick even while the
//!   floor is pressed; and detected corruption is never served
//!   uncorrected while degraded.

use dlrm_abft::coordinator::{BatchPolicy, ChaosConfig, Client, Engine, ScoreRequest, Server};
use dlrm_abft::dlrm::{DlrmConfig, DlrmModel, Protection, TableConfig};
use dlrm_abft::policy::{
    DetectionMode, OverloadConfig, OverloadFloor, OverloadState, PolicyConfig,
};
use dlrm_abft::util::json::Json;
use dlrm_abft::util::rng::Pcg32;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

#[cfg(target_os = "linux")]
use dlrm_abft::coordinator::{AsyncServer, ReactorOptions};
#[cfg(target_os = "linux")]
use std::io::{BufRead, BufReader, BufWriter, Write};
#[cfg(target_os = "linux")]
use std::net::TcpStream;

fn cfg(protection: Protection) -> DlrmConfig {
    DlrmConfig {
        num_dense: 6,
        embedding_dim: 16,
        bottom_mlp: vec![32, 16],
        top_mlp: vec![32],
        tables: vec![
            TableConfig { rows: 2_000, pooling: 10 },
            TableConfig { rows: 1_000, pooling: 5 },
        ],
        protection,
        dense_range: (0.0, 1.0),
        seed: 21,
    }
}

fn requests(model: &DlrmModel, n: usize, seed: u64) -> Vec<ScoreRequest> {
    let mut rng = Pcg32::new(seed);
    model
        .synth_requests(n, &mut rng)
        .into_iter()
        .enumerate()
        .map(|(i, r)| ScoreRequest { id: i as u64, dense: r.dense, sparse: r.sparse })
        .collect()
}

fn policy() -> BatchPolicy {
    BatchPolicy {
        max_batch: 8,
        max_wait: Duration::from_millis(1),
        max_queue: 256,
        loops: 1,
    }
}

/// Manual-tick policy config (no background controller thread) so the
/// drill steps are fully deterministic.
fn manual_policy_cfg() -> PolicyConfig {
    PolicyConfig {
        tick: Duration::ZERO,
        cooldown_ticks: 2,
        decay_patience: 1,
        ..PolicyConfig::default()
    }
}

/// Push a hot latency window into the engine's histogram, then run one
/// overload tick at the given queue depth.
fn hot_tick(engine: &Engine, depth: usize, bound: usize) {
    for _ in 0..50 {
        engine.metrics.latency.record_us(50_000);
    }
    engine.overload_tick(depth, bound);
}

fn calm_tick(engine: &Engine, bound: usize) {
    for _ in 0..50 {
        engine.metrics.latency.record_us(100);
    }
    engine.overload_tick(0, bound);
}

#[cfg(target_os = "linux")]
#[test]
fn async_scores_bit_identical_to_threaded() {
    // Twin engines from the same seed behind the two front ends: every
    // score must agree to the bit. Then the async engine is pressed to
    // its detection floor and rescored — degraded detection must not
    // move clean scores either (the policy-lattice safety invariant,
    // here asserted across the wire).
    let reqs = requests(&DlrmModel::random(cfg(Protection::DetectRecompute)), 16, 11);
    let threaded_engine = Arc::new(Engine::new(DlrmModel::random(cfg(Protection::DetectRecompute))));
    let async_engine = Arc::new(
        Engine::new(DlrmModel::random(cfg(Protection::DetectRecompute)))
            .with_policy(manual_policy_cfg())
            .with_overload(OverloadConfig::for_slo_ms(1)),
    );
    let t_server = Server::start("127.0.0.1:0", Arc::clone(&threaded_engine), policy()).unwrap();
    let a_server = AsyncServer::start(
        "127.0.0.1:0",
        Arc::clone(&async_engine),
        policy(),
        ReactorOptions::default(),
    )
    .unwrap();
    let mut tc = Client::connect(&t_server.addr).unwrap();
    let mut ac = Client::connect(&a_server.addr).unwrap();
    let mut threaded_scores = Vec::new();
    for req in &reqs {
        let tr = tc.score(req).unwrap();
        let ar = ac.score(req).unwrap();
        assert_eq!(tr.id, ar.id);
        assert_eq!(
            tr.score.to_bits(),
            ar.score.to_bits(),
            "async front end must not move scores (id {})",
            req.id
        );
        assert!(!ar.detected);
        threaded_scores.push(tr.score);
    }
    // Press the async engine's detection floor (latency pressure only —
    // the queue stays shallow, so nothing sheds and traffic still
    // flows).
    let ctl = Arc::clone(async_engine.overload().unwrap());
    for _ in 0..8 {
        hot_tick(&async_engine, 0, 64);
    }
    assert_ne!(ctl.floor(), OverloadFloor::None, "floor must be pressed");
    for (req, want) in reqs.iter().zip(&threaded_scores) {
        let ar = ac.score(req).unwrap();
        assert_eq!(
            ar.score.to_bits(),
            want.to_bits(),
            "degraded detection must not move clean scores (id {})",
            req.id
        );
    }
    t_server.stop();
    a_server.stop();
}

#[cfg(target_os = "linux")]
#[test]
fn admission_rejects_past_watermark_and_recovers() {
    let model = DlrmModel::random(cfg(Protection::Detect));
    let reqs = requests(&model, 4, 9);
    let engine = Arc::new(Engine::new(model));
    // A queue of two and a long cut: pipelined requests park in the
    // queue deterministically while a third one bounces.
    let tight = BatchPolicy {
        max_batch: 64,
        max_wait: Duration::from_millis(600),
        max_queue: 2,
        loops: 1,
    };
    let server =
        AsyncServer::start("127.0.0.1:0", Arc::clone(&engine), tight, ReactorOptions::default())
            .unwrap();
    let a = TcpStream::connect(server.addr).unwrap();
    let mut aw = BufWriter::new(a.try_clone().unwrap());
    let mut ar = BufReader::new(a);
    writeln!(aw, "{}", reqs[0].to_json()).unwrap();
    writeln!(aw, "{}", reqs[1].to_json()).unwrap();
    aw.flush().unwrap();
    std::thread::sleep(Duration::from_millis(150)); // let the reactor enqueue both
    // Past the watermark: one-line overload reply, immediately.
    let mut bc = Client::connect(&server.addr).unwrap();
    let err = bc.score(&reqs[2]).unwrap_err();
    assert!(err.to_string().contains("overloaded"), "{err}");
    // The queued pair drains at the batch cut...
    let mut line = String::new();
    ar.read_line(&mut line).unwrap();
    assert!(line.contains("score"), "{line}");
    line.clear();
    ar.read_line(&mut line).unwrap();
    assert!(line.contains("score"), "{line}");
    // ...and admission recovers: the bounced client is served.
    let resp = bc.score(&reqs[3]).unwrap();
    assert_eq!(resp.id, reqs[3].id);
    assert!(engine.metrics.shed.load(Ordering::Relaxed) >= 1);
    assert!(engine.metrics.admitted.load(Ordering::Relaxed) >= 3);
    server.stop();
}

#[test]
fn overload_drill_degrades_detection_strictly_before_shedding() {
    let engine = Engine::new(DlrmModel::random(cfg(Protection::DetectRecompute)))
        .with_policy(manual_policy_cfg())
        .with_overload(OverloadConfig::for_slo_ms(1));
    let ctl = Arc::clone(engine.overload().unwrap());
    let sites = Arc::clone(engine.policy_sites().unwrap());
    let bound = 64usize;
    // Sustained pressure: the floor must walk Budgeted → BoundOnly while
    // the controller is still only Degrading; shedding comes last.
    let mut saw_budgeted_before_shed = false;
    let mut saw_bound_only_before_shed = false;
    for _ in 0..20 {
        hot_tick(&engine, bound, bound);
        if ctl.state() == OverloadState::Shedding {
            break;
        }
        saw_budgeted_before_shed |= ctl.floor() == OverloadFloor::Budgeted;
        saw_bound_only_before_shed |= ctl.floor() == OverloadFloor::BoundOnly;
        assert!(
            !ctl.should_shed(bound, bound),
            "no shed before the floor is exhausted"
        );
    }
    assert!(saw_budgeted_before_shed, "skipped the budgeted floor");
    assert!(saw_bound_only_before_shed, "skipped the bound-only floor");
    assert_eq!(ctl.state(), OverloadState::Shedding);
    assert!(ctl.should_shed(bound, bound));
    // With the floor fully pressed, every (non-cooldown) site sits at
    // BoundOnly — detection was spent down before a single shed.
    for g in &sites.gemm {
        assert_eq!(g.cell.load(), DetectionMode::BoundOnly);
    }
    for e in &sites.eb {
        assert_eq!(e.cell.load(), DetectionMode::BoundOnly);
    }
    // Pressure clears → the ladder unwinds with hysteresis back to
    // Normal, and the floor lift restores modes the policy itself would
    // never have chosen.
    for _ in 0..40 {
        calm_tick(&engine, bound);
        if ctl.state() == OverloadState::Normal && ctl.floor() == OverloadFloor::None {
            break;
        }
    }
    assert_eq!(ctl.state(), OverloadState::Normal);
    assert_eq!(ctl.floor(), OverloadFloor::None);
    for g in &sites.gemm {
        assert_ne!(g.cell.load(), DetectionMode::BoundOnly, "floor lift must restore");
    }
    for e in &sites.eb {
        assert_ne!(e.cell.load(), DetectionMode::BoundOnly, "floor lift must restore");
    }
    assert!(ctl.degrade_steps() >= 2);
    assert!(ctl.restore_steps() >= 2);
}

#[test]
fn fault_escalates_past_the_floor_and_corruption_is_never_served() {
    // A chaos engine, degraded by overload pressure: an injected fault
    // must snap its site back to Full within one policy tick (the floor
    // skips cooling sites), and every detection on served traffic must
    // resolve as recovered — detected corruption never reaches a reply.
    let engine = Engine::with_chaos(
        DlrmModel::random(cfg(Protection::DetectRecompute)),
        ChaosConfig { p_weight_flip: 1.0, p_table_flip: 0.0, seed: 5 },
    )
    .with_policy(manual_policy_cfg())
    .with_overload(OverloadConfig::for_slo_ms(1));
    let ctl = Arc::clone(engine.overload().unwrap());
    let sites = Arc::clone(engine.policy_sites().unwrap());
    let bound = 64usize;
    for _ in 0..8 {
        hot_tick(&engine, bound, bound);
    }
    assert_eq!(ctl.floor(), OverloadFloor::BoundOnly, "drill starts fully degraded");
    for g in &sites.gemm {
        assert_eq!(g.cell.load(), DetectionMode::BoundOnly);
    }
    // Fault signal on every GEMM site: one tick later they are Full,
    // floor or no floor.
    for g in &sites.gemm {
        g.telem.note_flags(1);
    }
    let rep = engine.policy_tick().expect("policy attached");
    assert!(rep.escalations >= sites.gemm.len(), "escalation must beat the floor");
    for g in &sites.gemm {
        assert_eq!(g.cell.load(), DetectionMode::Full);
    }
    // The floor keeps pressing while hot — but not the escalated sites.
    hot_tick(&engine, bound, bound);
    for g in &sites.gemm {
        assert_eq!(g.cell.load(), DetectionMode::Full, "cooldown sites are floor-exempt");
    }
    // Serve chaos traffic with detection escalated (EB sites still
    // degraded): everything detected must be repaired before replying.
    let reqs = requests(&DlrmModel::random(cfg(Protection::DetectRecompute)), 12, 2);
    let mut detected_any = false;
    for _round in 0..5 {
        for resp in engine.process_batch(reqs.clone()) {
            if resp.detected {
                detected_any = true;
                assert!(!resp.degraded, "detected corruption must be repaired, not served");
            }
        }
    }
    assert!(detected_any, "p=1.0 weight chaos never detected at Full");
    // The journal saw the faults (the drill's post-mortem query).
    let ev = engine.events_json(64);
    assert!(
        ev.path(&["counts", "total"]).and_then(Json::as_usize).unwrap_or(0) >= 1,
        "journal must carry the detected faults: {ev}"
    );
}
