"""Kernel-vs-oracle validation: the CORE correctness signal for L1.

Randomized shape sweeps (fixed seeds, hypothesis-style) of the Pallas
ABFT GEMM and EmbeddingBag kernels against the pure-jnp references, plus
checksum-algebra properties and fault-injection detection checks.
"""

import numpy as np
import pytest
import jax.numpy as jnp

from compile.kernels import abft_gemm, embeddingbag, ref


def rand_case(rng, m, k, n):
    a = jnp.asarray(rng.integers(0, 256, (m, k), dtype=np.uint8))
    b = jnp.asarray(rng.integers(-128, 128, (k, n), dtype=np.int8))
    return a, b


# ---------------------------------------------------------------------------
# ABFT GEMM kernel
# ---------------------------------------------------------------------------

GEMM_SHAPES = [
    (1, 1, 1),
    (1, 3200, 800),
    (2, 7, 5),
    (5, 257, 63),
    (16, 512, 512),
    (33, 100, 40),
]


@pytest.mark.parametrize("m,k,n", GEMM_SHAPES)
def test_gemm_matches_ref(m, k, n):
    rng = np.random.default_rng(m * 7919 + k * 13 + n)
    a, b = rand_case(rng, m, k, n)
    b_enc = ref.encode(b)
    c = abft_gemm.abft_qgemm(a, b_enc)
    c_ref = ref.abft_qgemm_ref(a, b_enc)
    assert (np.asarray(c) == np.asarray(c_ref)).all()


@pytest.mark.parametrize("seed", range(8))
def test_gemm_random_shape_sweep(seed):
    rng = np.random.default_rng(seed)
    m = int(rng.integers(1, 24))
    k = int(rng.integers(1, 300))
    n = int(rng.integers(1, 200))
    a, b = rand_case(rng, m, k, n)
    b_enc = ref.encode(b)
    c = abft_gemm.abft_qgemm(a, b_enc)
    assert (np.asarray(c) == np.asarray(ref.abft_qgemm_ref(a, b_enc))).all()
    # Clean run: all residuals zero both via kernel and via ref.
    assert int(abft_gemm.err_count(c)) == 0
    assert (np.asarray(ref.verify_rows(c)) == 0).all()


@pytest.mark.parametrize("bm,bn,bk", [(1, 8, 8), (4, 32, 16), (8, 128, 128)])
def test_gemm_block_shape_invariance(bm, bn, bk):
    rng = np.random.default_rng(99)
    a, b = rand_case(rng, 6, 70, 45)
    b_enc = ref.encode(b)
    c = abft_gemm.abft_qgemm(a, b_enc, bm=bm, bn=bn, bk=bk)
    assert (np.asarray(c) == np.asarray(ref.abft_qgemm_ref(a, b_enc))).all()


def test_encode_matches_rust_convention():
    # Truncated remainder: -300 % 127 -> -46 (rust), not 81 (python).
    b = jnp.asarray(np.full((1, 3), -100, dtype=np.int8))
    col = ref.encode_checksum_col(b)
    assert int(col[0]) == -(300 % 127)


def test_checksum_col_fits_i8():
    rng = np.random.default_rng(3)
    _, b = rand_case(rng, 1, 64, 333)
    col = np.asarray(ref.encode_checksum_col(b))
    assert col.dtype == np.int8
    assert (np.abs(col.astype(np.int32)) < 127).all()


def test_bitflip_in_c_always_detected():
    rng = np.random.default_rng(5)
    a, b = rand_case(rng, 4, 64, 32)
    c = np.asarray(abft_gemm.abft_qgemm(a, ref.encode(b))).copy()
    for bit in [0, 7, 15, 23, 30]:
        c2 = c.copy()
        c2[2, 10] ^= np.int32(1 << bit)
        residues = np.asarray(abft_gemm.verify_rows(jnp.asarray(c2)))
        assert residues[2] != 0, f"bit {bit} escaped"
        assert (residues[[0, 1, 3]] == 0).all()


def test_delta_multiple_of_127_escapes():
    rng = np.random.default_rng(6)
    a, b = rand_case(rng, 2, 16, 8)
    c = np.asarray(abft_gemm.abft_qgemm(a, ref.encode(b))).copy()
    c[0, 3] += 127 * 4
    assert int(abft_gemm.err_count(jnp.asarray(c))) == 0  # §IV-C false negative
    c[0, 3] += 1
    assert int(abft_gemm.err_count(jnp.asarray(c))) == 1


def test_bitflip_in_b_detected_as_column_corruption():
    rng = np.random.default_rng(7)
    m, k, n = 8, 32, 16
    a, b = rand_case(rng, m, k, n)
    b_enc = np.asarray(ref.encode(b)).copy()
    b_enc[5, 3] ^= 0x10  # payload flip after encoding
    c = abft_gemm.abft_qgemm(a, jnp.asarray(b_enc))
    # Whole-column corruption: most rows should flag (3/256 per-row miss).
    assert int(abft_gemm.err_count(c)) >= m - 1


# ---------------------------------------------------------------------------
# EmbeddingBag kernel
# ---------------------------------------------------------------------------

EB_CASES = [
    (100, 8, 1, 1),
    (500, 32, 4, 20),
    (2000, 64, 10, 100),
    (750, 128, 3, 37),
]


@pytest.mark.parametrize("rows,d,batch,pooling", EB_CASES)
def test_eb_matches_ref(rows, d, batch, pooling):
    rng = np.random.default_rng(rows + d)
    table = jnp.asarray(rng.integers(0, 256, (rows, d), dtype=np.uint8))
    alpha = jnp.asarray(rng.uniform(0.005, 0.02, rows).astype(np.float32))
    beta = jnp.asarray(rng.uniform(-1, 1, rows).astype(np.float32))
    c_t = ref.eb_checksum_ref(table)
    idx = jnp.asarray(rng.integers(0, rows, (batch, pooling), dtype=np.int32))
    out, rsum, csum = embeddingbag.eb_abft(table, alpha, beta, c_t, idx)
    out_ref = ref.eb_ref(table, alpha, beta, idx)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out_ref), rtol=2e-5, atol=1e-4)
    # Clean bags must not flag.
    assert not np.asarray(embeddingbag.flag_bags(rsum, csum)).any()
    # rsum really is the output row sum.
    np.testing.assert_allclose(
        np.asarray(rsum), np.asarray(out).sum(axis=1), rtol=1e-4, atol=1e-2
    )


def test_eb_high_bit_table_flip_flagged():
    rng = np.random.default_rng(11)
    rows, d, batch, pooling = 400, 32, 2, 50
    table = rng.integers(0, 256, (rows, d), dtype=np.uint8)
    alpha = rng.uniform(0.005, 0.02, rows).astype(np.float32)
    beta = rng.uniform(-1, 1, rows).astype(np.float32)
    c_t = np.asarray(ref.eb_checksum_ref(jnp.asarray(table)))
    idx = rng.integers(0, rows, (batch, pooling), dtype=np.int32)
    victim = int(idx[0, 0])
    table_bad = table.copy()
    table_bad[victim, 0] ^= 0x80  # top bit
    out, rsum, csum = embeddingbag.eb_abft(
        jnp.asarray(table_bad),
        jnp.asarray(alpha),
        jnp.asarray(beta),
        jnp.asarray(c_t),
        jnp.asarray(idx),
    )
    flags = np.asarray(embeddingbag.flag_bags(rsum, csum))
    assert flags[0], "high-bit flip must be flagged"


def test_eb_verify_ref_agrees_with_kernel_sums():
    rng = np.random.default_rng(12)
    rows, d, batch, pooling = 300, 16, 5, 30
    table = jnp.asarray(rng.integers(0, 256, (rows, d), dtype=np.uint8))
    alpha = jnp.asarray(rng.uniform(0.005, 0.02, rows).astype(np.float32))
    beta = jnp.asarray(rng.uniform(-1, 1, rows).astype(np.float32))
    c_t = ref.eb_checksum_ref(table)
    idx = jnp.asarray(rng.integers(0, rows, (batch, pooling), dtype=np.int32))
    out, rsum, csum = embeddingbag.eb_abft(table, alpha, beta, c_t, idx)
    ref_flags = np.asarray(ref.eb_verify_ref(out, c_t, alpha, beta, idx, d))
    kern_flags = np.asarray(embeddingbag.flag_bags(rsum, csum))
    assert (ref_flags == kern_flags).all()
