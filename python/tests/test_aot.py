"""AOT artifact regression tests — the interchange contract with rust.

These pin the two failure modes discovered during bring-up (see
DESIGN.md §Findings): elided large constants and serialized-proto
incompatibility. If these fail, the rust side will load garbage weights
or refuse the artifact entirely.
"""

import re

import numpy as np
import jax.numpy as jnp
import pytest

from compile import aot, model as model_mod


@pytest.fixture(scope="module")
def model_text():
    return aot.to_hlo_text(aot.lower_model(batch=1))


def test_no_elided_constants(model_text):
    # `constant({...})` is the elision marker; the 0.5.1 text parser reads
    # it as garbage instead of erroring. Must never appear.
    assert "constant({...})" not in model_text
    assert "..." not in model_text, "any ellipsis in HLO text means elision"


def test_constants_carry_real_payload(model_text):
    # The baked i8 weight panels must appear as literal arrays: look for a
    # wide s8 constant with actual digits.
    m = re.search(r"s8\[\d+,\d+\]\{1,0\} constant\(\{ \{", model_text)
    assert m, "no materialized s8 weight constant found"


def test_gemm_kernel_text_is_plain_hlo():
    text = aot.to_hlo_text(aot.lower_gemm_kernel())
    assert "ENTRY" in text
    # interpret=True must not leave Mosaic custom-calls behind.
    assert "mosaic" not in text.lower()
    for ty in ("u8[", "s8[", "s32["):
        assert ty in text, f"missing {ty} in kernel HLO"


def test_model_batch_consistency():
    # The same request must score identically through model_b1 and as the
    # first row of model_b8 (static quantization — no batch coupling).
    params = model_mod.make_model()
    cfg = params["cfg"]
    rng = np.random.default_rng(3)
    dense1 = rng.uniform(0, 1, (1, cfg["num_dense"])).astype(np.float32)
    idx1 = rng.integers(0, min(cfg["tables"]), (1, len(cfg["tables"]), cfg["pooling"])).astype(
        np.int32
    )
    dense8 = np.repeat(dense1, 8, axis=0)
    idx8 = np.repeat(idx1, 8, axis=0)
    s1, _, _ = model_mod.forward(params, jnp.asarray(dense1), jnp.asarray(idx1))
    s8, _, _ = model_mod.forward(params, jnp.asarray(dense8), jnp.asarray(idx8))
    np.testing.assert_allclose(np.asarray(s8), float(s1[0]), rtol=1e-6)


def test_artifact_shapes_documented_in_aot():
    # The rust integration tests hardcode these; fail loudly on drift.
    assert (aot.GEMM_M, aot.GEMM_K, aot.GEMM_N) == (16, 512, 512)
    assert (aot.EB_ROWS, aot.EB_D, aot.EB_BATCH, aot.EB_POOL) == (10_000, 64, 10, 100)
    assert aot.MODEL_BATCHES == (1, 8)
