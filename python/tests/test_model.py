"""L2 model tests: shapes, ABFT evidence outputs, detection through the
full graph, and AOT lowering round-trips."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from compile import aot, model as model_mod


TINY_CFG = {
    "num_dense": 4,
    "embedding_dim": 16,
    "bottom_mlp": [32, 16],
    "top_mlp": [32],
    "tables": [300, 200],
    "pooling": 10,
    "seed": 7,
}


@pytest.fixture(scope="module")
def params():
    return model_mod.make_model(TINY_CFG)


def synth_inputs(params, batch, seed=0):
    cfg = params["cfg"]
    rng = np.random.default_rng(seed)
    dense = jnp.asarray(rng.uniform(0, 1, (batch, cfg["num_dense"])).astype(np.float32))
    idx = np.stack(
        [
            rng.integers(0, rows, (batch, cfg["pooling"]))
            for rows in cfg["tables"]
        ],
        axis=1,
    ).astype(np.int32)
    return dense, jnp.asarray(idx)


def test_forward_shapes_and_ranges(params):
    dense, idx = synth_inputs(params, 6)
    scores, gemm_bad, eb_flagged = model_mod.forward(params, dense, idx)
    assert scores.shape == (6,)
    s = np.asarray(scores)
    assert ((s >= 0) & (s <= 1)).all()
    assert int(gemm_bad) == 0
    assert int(eb_flagged) == 0


def test_forward_deterministic(params):
    dense, idx = synth_inputs(params, 3, seed=5)
    s1, _, _ = model_mod.forward(params, dense, idx)
    s2, _, _ = model_mod.forward(params, dense, idx)
    assert (np.asarray(s1) == np.asarray(s2)).all()


def test_corrupted_weight_detected_through_graph(params):
    import copy

    p2 = {**params, "bottom": [dict(l) for l in params["bottom"]]}
    b_enc = np.asarray(p2["bottom"][0]["b_enc"]).copy()
    b_enc[2, 3] = np.int8(b_enc[2, 3] ^ 0x40)  # payload bit flip post-encode
    p2["bottom"][0] = {**p2["bottom"][0], "b_enc": jnp.asarray(b_enc)}
    dense, idx = synth_inputs(params, 4, seed=9)
    _, gemm_bad, _ = model_mod.forward(p2, dense, idx)
    assert int(gemm_bad) > 0, "post-encode weight corruption must be flagged"


def test_corrupted_table_detected_through_graph(params):
    p2 = {**params, "tables": [dict(t) for t in params["tables"]]}
    codes = np.asarray(p2["tables"][0]["codes"]).copy()
    codes[:, 0] ^= 0x80  # corrupt every row's first code: any bag hits it
    p2["tables"][0] = {**p2["tables"][0], "codes": jnp.asarray(codes)}
    dense, idx = synth_inputs(params, 4, seed=11)
    _, _, eb_flagged = model_mod.forward(p2, dense, idx)
    assert int(eb_flagged) > 0


def test_interaction_matches_manual():
    feats = jnp.asarray(
        np.arange(2 * 3 * 4, dtype=np.float32).reshape(2, 3, 4)
    )
    got = np.asarray(model_mod.pairwise_interaction(feats))
    for b in range(2):
        manual = []
        for g1 in range(3):
            for g2 in range(g1 + 1, 3):
                manual.append(float(np.dot(feats[b, g1], feats[b, g2])))
        np.testing.assert_allclose(got[b], manual, rtol=1e-6)


# ---------------------------------------------------------------------------
# AOT lowering
# ---------------------------------------------------------------------------


def test_lowered_gemm_kernel_parses_and_runs():
    lowered = aot.lower_gemm_kernel()
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text
    # Execute the lowered computation via jax and compare with direct call.
    rng = np.random.default_rng(1)
    a = jnp.asarray(rng.integers(0, 256, (aot.GEMM_M, aot.GEMM_K), dtype=np.uint8))
    b = jnp.asarray(
        rng.integers(-128, 128, (aot.GEMM_K, aot.GEMM_N + 1), dtype=np.int8)
    )
    compiled = lowered.compile()
    c, residuals = compiled(a, b)
    from compile.kernels import abft_gemm

    c2 = abft_gemm.abft_qgemm(a, b)
    assert (np.asarray(c) == np.asarray(c2)).all()
    assert residuals.shape == (aot.GEMM_M,)


def test_lowered_model_executes(tmp_path):
    lowered = aot.lower_model(batch=1)
    compiled = lowered.compile()
    params = model_mod.make_model()
    cfg = params["cfg"]
    rng = np.random.default_rng(2)
    dense = jnp.asarray(rng.uniform(0, 1, (1, cfg["num_dense"])).astype(np.float32))
    idx = jnp.asarray(
        rng.integers(0, min(cfg["tables"]), (1, len(cfg["tables"]), cfg["pooling"]))
        .astype(np.int32)
    )
    scores, gemm_bad, eb_flagged = compiled(dense, idx)
    assert 0.0 <= float(scores[0]) <= 1.0
    assert int(gemm_bad) == 0
    assert int(eb_flagged) == 0


def test_hlo_text_has_no_custom_calls():
    # interpret=True must lower to plain HLO the CPU PJRT client can run —
    # a Mosaic custom-call would break the rust loader.
    text = aot.to_hlo_text(aot.lower_gemm_kernel())
    assert "custom-call" not in text or "mosaic" not in text.lower()
