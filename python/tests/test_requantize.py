"""Requantization kernel vs a plain-jnp reference (Eq 1 / Fig 1, §IV-A3
exclude-checksum semantics)."""

import numpy as np
import pytest
import jax.numpy as jnp

from compile.kernels import abft_gemm, ref, requantize


def reference_requant(c, arow, bcol, x_qp, w_qp, out_qp, k, relu):
    payload = np.asarray(c)[:, :-1].astype(np.float32)
    real = (
        x_qp[0] * w_qp[0] * payload
        + x_qp[0] * w_qp[1] * np.asarray(arow, dtype=np.float32)[:, None]
        + w_qp[0] * x_qp[1] * np.asarray(bcol, dtype=np.float32)[None, :]
        + k * x_qp[1] * w_qp[1]
    )
    y = np.clip(np.round((real - out_qp[1]) / out_qp[0]), 0, 255)
    if relu:
        zero = np.clip(np.round((0.0 - out_qp[1]) / out_qp[0]), 0, 255)
        y = np.maximum(y, zero)
    return y.astype(np.uint8)


@pytest.mark.parametrize("m,k,n,relu", [(1, 16, 8, False), (5, 64, 32, True), (16, 128, 64, True)])
def test_kernel_matches_reference(m, k, n, relu):
    rng = np.random.default_rng(m * 31 + k)
    a = jnp.asarray(rng.integers(0, 256, (m, k), dtype=np.uint8))
    b = jnp.asarray(rng.integers(-128, 128, (k, n), dtype=np.int8))
    b_enc = ref.encode(b)
    c = abft_gemm.abft_qgemm(a, b_enc)
    arow = jnp.sum(a.astype(jnp.int32), axis=1)
    bcol = jnp.sum(b.astype(jnp.int32), axis=0)
    x_qp = (np.float32(1 / 255), np.float32(0.0))
    w_qp = (np.float32(0.01), np.float32(-0.5))
    out_qp = (np.float32(8.4 / 255), np.float32(-4.0))
    got = requantize.requantize_exclude_last_col(c, arow, bcol, x_qp, w_qp, out_qp, k, relu=relu)
    want = reference_requant(c, arow, bcol, x_qp, w_qp, out_qp, k, relu)
    # round() ties (x.5) may resolve differently across backends; allow
    # off-by-one codes at exact ties, exact match elsewhere.
    diff = np.abs(np.asarray(got).astype(np.int32) - want.astype(np.int32))
    assert diff.max() <= 1
    assert (diff > 0).mean() < 0.02


def test_checksum_column_really_excluded():
    rng = np.random.default_rng(9)
    m, k, n = 3, 8, 6
    a = jnp.asarray(rng.integers(0, 256, (m, k), dtype=np.uint8))
    b = jnp.asarray(rng.integers(-128, 128, (k, n), dtype=np.int8))
    c = np.asarray(abft_gemm.abft_qgemm(a, ref.encode(b))).copy()
    arow = jnp.asarray(np.asarray(a).astype(np.int32).sum(axis=1))
    bcol = jnp.asarray(np.asarray(b).astype(np.int32).sum(axis=0))
    qp = (np.float32(0.01), np.float32(0.0))
    out = (np.float32(0.1), np.float32(-10.0))
    y1 = requantize.requantize_exclude_last_col(jnp.asarray(c), arow, bcol, qp, qp, out, k)
    c[:, -1] = 0x7FFFFFF  # trash the checksum column
    y2 = requantize.requantize_exclude_last_col(jnp.asarray(c), arow, bcol, qp, qp, out, k)
    assert (np.asarray(y1) == np.asarray(y2)).all(), "checksum column leaked into output"


def test_output_shape_drops_column():
    c = jnp.zeros((4, 11), jnp.int32)
    y = requantize.requantize_exclude_last_col(
        c,
        jnp.zeros((4,), jnp.int32),
        jnp.zeros((10,), jnp.int32),
        (np.float32(1), np.float32(0)),
        (np.float32(1), np.float32(0)),
        (np.float32(1), np.float32(0)),
        7,
    )
    assert y.shape == (4, 10)
    assert y.dtype == jnp.uint8
