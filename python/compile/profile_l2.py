"""§Perf L1/L2 profiling: XLA cost analysis of the lowered graphs plus the
VMEM/MXU estimate for the Pallas kernel's BlockSpec schedule.

L1 note: `interpret=True` timings are CPU-numpy, not a TPU proxy — we
optimize *structure* (block shapes, VMEM footprint, MXU utilization
estimate) and measure wallclock only at L3 (rust). Run:

    cd python && python -m compile.profile_l2
"""

import jax.numpy as jnp

from . import aot


def cost(lowered, name):
    compiled = lowered.compile()
    ca = compiled.cost_analysis()
    if isinstance(ca, list):  # older jax returns one dict per device
        ca = ca[0]
    flops = ca.get("flops", float("nan"))
    bytes_ = ca.get("bytes accessed", float("nan"))
    print(f"{name:<14} flops={flops:>14.3e}  bytes={bytes_:>12.3e}  "
          f"arith.intensity={flops / max(bytes_, 1):>7.2f}")
    return ca


def vmem_mxu_estimate(bm, bn, bk, m, n1, k):
    """Static VMEM/MXU estimate for the ABFT GEMM BlockSpec (DESIGN.md §8).

    Per grid step the kernel holds one A tile (bm×bk u8), one B' tile
    (bk×bn i8) and the C accumulator tile (bm×bn i32) in VMEM. MXU work
    overhead of protection is (n+1)/n (one extra RHS column).
    """
    vmem = bm * bk + bk * bn + bm * bn * 4
    print(f"L1 abft_gemm BlockSpec ({bm},{bn},{bk}):")
    print(f"  VMEM/step = {vmem} B ({vmem / 1024:.1f} KiB; TPU budget ~16 MiB)")
    n = n1 - 1
    print(f"  MXU overhead of checksum column = (n+1)/n - 1 = {100.0 / n:.3f}%")
    steps = ((m + bm - 1) // bm) * ((n1 + bn - 1) // bn) * ((k + bk - 1) // bk)
    print(f"  grid steps = {steps}; HBM traffic/step = A {bm*bk}B + B' {bk*bn}B")
    # MXU utilization estimate: u8 operands on the 128x128 systolic array.
    util_m = min(bm, 128) / 128
    util_n = min(bn, 128) / 128
    print(f"  MXU tile fill = {util_m * util_n * 100:.1f}% "
          f"(bm={bm} of 128 rows, bn={bn} of 128 cols)")


def main():
    print("== L2: XLA cost analysis of the AOT artifacts ==")
    cost(aot.lower_gemm_kernel(), "abft_gemm")
    cost(aot.lower_eb_kernel(), "eb_bag")
    cost(aot.lower_model(1), "model_b1")
    cost(aot.lower_model(8), "model_b8")
    print()
    print("== L1: Pallas ABFT GEMM structural estimate ==")
    vmem_mxu_estimate(8, 128, 128, aot.GEMM_M, aot.GEMM_N + 1, aot.GEMM_K)


if __name__ == "__main__":
    main()
