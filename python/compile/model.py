"""Layer-2: the quantized DLRM forward graph in JAX, calling the Layer-1
Pallas kernels, with ABFT verification fused into the graph.

The lowered artifact *returns the ABFT evidence* alongside the scores —
`gemm_bad_rows` (Alg 1's errCount summed over layers) and `eb_flagged`
(Eq-5 violations over all bags) — so the rust coordinator can apply its
recompute policy without re-entering python.

Everything here is build-time only: `aot.py` lowers `forward` to HLO text
once and the rust runtime serves it from then on.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import abft_gemm, embeddingbag, ref, requantize


# --------------------------------------------------------------------------
# Parameter construction (mirrors rust DlrmModel::random)
# --------------------------------------------------------------------------


def _fit_u8(lo, hi):
    alpha = (hi - lo) / 255.0
    return np.float32(alpha), np.float32(lo)


def make_linear(rng, k, n, relu, out_bound):
    """He-initialized float weights, quantized to i8 and ABFT-encoded.

    The output lattice is slightly asymmetric so the quantized zero code
    avoids 127/128 (code 127 ≡ 0 mod 127 hides B-errors under ReLU
    clamping — DESIGN.md §Findings).
    """
    w = rng.normal(0.0, np.sqrt(2.0 / k), (k, n)).astype(np.float32)
    lo, hi = float(w.min()), float(w.max())
    w_alpha = np.float32((hi - lo) / 255.0)
    w_beta = np.float32(lo + 128.0 * w_alpha)
    wq = np.clip(np.round((w - w_beta) / w_alpha), -128, 127).astype(np.int8)
    out_alpha, out_beta = _fit_u8(-out_bound, out_bound * 1.10)
    return {
        "b_enc": jnp.asarray(np.asarray(ref.encode(jnp.asarray(wq)))),
        "w_col_sums": jnp.asarray(wq.astype(np.int32).sum(axis=0)),
        "w_alpha": w_alpha,
        "w_beta": w_beta,
        "out_alpha": out_alpha,
        "out_beta": out_beta,
        "relu": relu,
        "k": k,
        "n": n,
    }


def make_table(rng, rows, d):
    codes = rng.integers(0, 256, (rows, d), dtype=np.uint8)
    alpha = rng.uniform(0.005, 0.02, rows).astype(np.float32)
    beta = rng.uniform(-1.0, 1.0, rows).astype(np.float32)
    return {
        "codes": jnp.asarray(codes),
        "alpha": jnp.asarray(alpha),
        "beta": jnp.asarray(beta),
        "c_t": jnp.asarray(codes.astype(np.int32).sum(axis=1)),
    }


DEFAULT_CFG = {
    "num_dense": 8,
    "embedding_dim": 32,
    "bottom_mlp": [64, 32],
    "top_mlp": [64],
    "tables": [5000, 5000],
    "pooling": 20,
    "dense_range": (0.0, 1.0),
    "seed": 42,
}


def make_model(cfg=None):
    cfg = {**DEFAULT_CFG, **(cfg or {})}
    assert cfg["bottom_mlp"][-1] == cfg["embedding_dim"]
    rng = np.random.default_rng(cfg["seed"])
    params = {"cfg": cfg, "bottom": [], "top": [], "tables": []}
    prev = cfg["num_dense"]
    for h in cfg["bottom_mlp"]:
        # ±4 covers ±3σ of He-init activations; wider ranges quantize all
        # outputs to one code and collapse the scores (see rust
        # AbftLinear::random for the derivation).
        params["bottom"].append(make_linear(rng, prev, h, True, 4.0))
        prev = h
    t = len(cfg["tables"]) + 1
    top_in = cfg["embedding_dim"] + t * (t - 1) // 2
    prev = top_in
    for h in cfg["top_mlp"]:
        params["top"].append(make_linear(rng, prev, h, True, 4.0))
        prev = h
    params["head"] = make_linear(rng, prev, 1, False, 4.0)
    for rows in cfg["tables"]:
        params["tables"].append(make_table(rng, rows, cfg["embedding_dim"]))
    da, db = _fit_u8(*cfg["dense_range"])
    params["dense_alpha"], params["dense_beta"] = da, db
    _calibrate_top(params, rng)
    return params


def _calibrate_top(params, rng):
    """Static-quantization calibration of the top-MLP input lattice
    (mirrors rust DlrmModel::calibrate): dynamic per-batch ranges would
    make a request's score depend on its batch-mates."""
    cfg = params["cfg"]
    batch = 32
    dense = jnp.asarray(rng.uniform(0, 1, (batch, cfg["num_dense"])).astype(np.float32))
    idx = np.stack(
        [rng.integers(0, rows, (batch, cfg["pooling"])) for rows in cfg["tables"]],
        axis=1,
    ).astype(np.int32)
    top_in = _compute_top_input(params, dense, jnp.asarray(idx))[0]
    # Per-column standardization stats: interaction features are orders of
    # magnitude larger than MLP features; a shared lattice without
    # standardization saturates the head (mirrors rust calibrate()).
    mean = jnp.mean(top_in, axis=0)
    std = jnp.maximum(jnp.std(top_in, axis=0), 1e-3)
    params["top_mean"], params["top_std"] = mean, std
    # Standardized features ~N(0,1); asymmetric ±4σ lattice keeps the zero
    # code off the modulus.
    alpha, beta = _fit_u8(-4.0, 4.4)
    params["top_alpha"], params["top_beta"] = alpha, beta


# --------------------------------------------------------------------------
# Forward graph
# --------------------------------------------------------------------------


def linear_forward(layer, x_q, x_alpha, x_beta):
    """Protected quantized FC: Alg-1 GEMM + fused requantization, both as
    Pallas kernels; the requantizer excludes the checksum column (§IV-A3)."""
    c = abft_gemm.abft_qgemm(x_q, layer["b_enc"])
    bad = abft_gemm.err_count(c)
    a_rowsums = jnp.sum(x_q.astype(jnp.int32), axis=1)
    y = requantize.requantize_exclude_last_col(
        c,
        a_rowsums,
        layer["w_col_sums"],
        (x_alpha, x_beta),
        (layer["w_alpha"], layer["w_beta"]),
        (layer["out_alpha"], layer["out_beta"]),
        layer["k"],
        relu=layer["relu"],
    )
    return y, bad


def dequant_u8(y, alpha, beta):
    return alpha * y.astype(jnp.float32) + beta


def pairwise_interaction(feats):
    """feats: (batch, groups, d) -> (batch, C(groups,2)) upper-tri dots."""
    gram = jnp.einsum("bgd,bhd->bgh", feats, feats)
    g = feats.shape[1]
    iu, ju = jnp.triu_indices(g, k=1)
    return gram[:, iu, ju]


def _compute_top_input(params, dense, indices):
    """Bottom half: bottom MLP -> EBs -> interaction -> concat."""
    x = jnp.clip(
        jnp.round((dense - params["dense_beta"]) / params["dense_alpha"]), 0, 255
    ).astype(jnp.uint8)
    x_alpha, x_beta = params["dense_alpha"], params["dense_beta"]

    gemm_bad = jnp.int32(0)
    for layer in params["bottom"]:
        x, bad = linear_forward(layer, x, x_alpha, x_beta)
        x_alpha, x_beta = layer["out_alpha"], layer["out_beta"]
        gemm_bad += bad
    bottom_f = dequant_u8(x, x_alpha, x_beta)  # (batch, d)

    # EmbeddingBags via the fused-checksum Pallas kernel.
    eb_flagged = jnp.int32(0)
    feats = [bottom_f]
    for t, table in enumerate(params["tables"]):
        out, rsum, csum = embeddingbag.eb_abft(
            table["codes"], table["alpha"], table["beta"], table["c_t"], indices[:, t, :]
        )
        eb_flagged += jnp.sum(
            embeddingbag.flag_bags(rsum, csum).astype(jnp.int32)
        )
        feats.append(out)
    stacked = jnp.stack(feats, axis=1)  # (batch, T+1, d)

    inter = pairwise_interaction(stacked)
    top_in = jnp.concatenate([bottom_f, inter], axis=1)
    return top_in, gemm_bad, eb_flagged


def forward(params, dense, indices):
    """Full protected DLRM forward.

    dense: (batch, num_dense) f32; indices: (batch, T, pooling) i32.
    Returns (scores (batch,), gemm_bad_rows i32, eb_flagged i32).
    """
    top_in, gemm_bad, eb_flagged = _compute_top_input(params, dense, indices)

    # Standardize per column (calibrated stats) then quantize onto the
    # static lattice.
    z = (top_in - params["top_mean"]) / params["top_std"]
    x_alpha, x_beta = params["top_alpha"], params["top_beta"]
    xq = jnp.clip(
        jnp.round((z - x_beta) / x_alpha), 0, 255
    ).astype(jnp.uint8)

    for layer in params["top"]:
        xq, bad = linear_forward(layer, xq, x_alpha, x_beta)
        x_alpha, x_beta = layer["out_alpha"], layer["out_beta"]
        gemm_bad += bad
    logits_q, bad = linear_forward(params["head"], xq, x_alpha, x_beta)
    gemm_bad += bad
    logits = dequant_u8(
        logits_q[:, 0], params["head"]["out_alpha"], params["head"]["out_beta"]
    )
    scores = jax.nn.sigmoid(logits)
    return scores, gemm_bad, eb_flagged


def make_jitted_forward(params):
    """Close over params (they become HLO constants) for AOT lowering."""

    @functools.partial(jax.jit)
    def fn(dense, indices):
        return forward(params, dense, indices)

    return fn
