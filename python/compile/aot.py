"""AOT lowering: jax → HLO *text* artifacts the rust runtime loads.

HLO text, NOT `.serialize()`: jax ≥ 0.5 emits HloModuleProto with 64-bit
instruction ids which the image's xla_extension 0.5.1 rejects
(`proto.id() <= INT_MAX`); the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Artifacts (all lowered with return_tuple=True):
  model_b{B}.hlo.txt — full protected DLRM forward, batch B
      inputs: dense f32[B, num_dense], indices i32[B, T, pooling]
      outputs: (scores f32[B], gemm_bad_rows i32[], eb_flagged i32[])
  abft_gemm.hlo.txt — standalone protected GEMM kernel
      inputs: a u8[M, K], b_enc i8[K, N+1]
      outputs: (c_temp i32[M, N+1], residuals i32[M])
  eb_bag.hlo.txt — standalone protected EmbeddingBag
      inputs: table u8[R, D], alpha f32[R], beta f32[R], c_t i32[R],
              indices i32[B, P]
      outputs: (result f32[B, D], rsum f32[B], csum f32[B])

Run via `make artifacts`; a no-op when artifacts are newer than sources.
"""

import argparse
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as model_mod
from .kernels import abft_gemm

# Shapes for the standalone kernel artifacts (one DLRM layer / Table-I bag).
GEMM_M, GEMM_K, GEMM_N = 16, 512, 512
EB_ROWS, EB_D, EB_BATCH, EB_POOL = 10_000, 64, 10, 100
MODEL_BATCHES = (1, 8)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True is load-bearing: the default ELIDES big
    # literals as `constant({...})`, which the 0.5.1 text parser silently
    # reads as garbage — baked weights would be corrupted on the rust side.
    return comp.as_hlo_text(print_large_constants=True)


def lower_model(batch: int):
    params = model_mod.make_model()
    cfg = params["cfg"]
    fn = model_mod.make_jitted_forward(params)
    dense = jax.ShapeDtypeStruct((batch, cfg["num_dense"]), jnp.float32)
    indices = jax.ShapeDtypeStruct(
        (batch, len(cfg["tables"]), cfg["pooling"]), jnp.int32
    )
    return jax.jit(fn).lower(dense, indices)


def lower_gemm_kernel():
    def fn(a, b_enc):
        c = abft_gemm.abft_qgemm(a, b_enc)
        return c, abft_gemm.verify_rows(c)

    a = jax.ShapeDtypeStruct((GEMM_M, GEMM_K), jnp.uint8)
    b_enc = jax.ShapeDtypeStruct((GEMM_K, GEMM_N + 1), jnp.int8)
    return jax.jit(fn).lower(a, b_enc)


def lower_eb_kernel():
    from .kernels import embeddingbag

    def fn(table, alpha, beta, c_t, indices):
        return embeddingbag.eb_abft(table, alpha, beta, c_t, indices)

    args = (
        jax.ShapeDtypeStruct((EB_ROWS, EB_D), jnp.uint8),
        jax.ShapeDtypeStruct((EB_ROWS,), jnp.float32),
        jax.ShapeDtypeStruct((EB_ROWS,), jnp.float32),
        jax.ShapeDtypeStruct((EB_ROWS,), jnp.int32),
        jax.ShapeDtypeStruct((EB_BATCH, EB_POOL), jnp.int32),
    )
    return jax.jit(fn).lower(*args)


def write(path: str, text: str):
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        f.write(text)
    print(f"wrote {len(text):>9} chars  {path}", file=sys.stderr)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    out = args.out_dir

    write(os.path.join(out, "abft_gemm.hlo.txt"), to_hlo_text(lower_gemm_kernel()))
    write(os.path.join(out, "eb_bag.hlo.txt"), to_hlo_text(lower_eb_kernel()))
    for b in MODEL_BATCHES:
        write(os.path.join(out, f"model_b{b}.hlo.txt"), to_hlo_text(lower_model(b)))


if __name__ == "__main__":
    main()
