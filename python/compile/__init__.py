"""Build-time python package: L1 Pallas kernels, L2 JAX DLRM graph, AOT
lowering to HLO-text artifacts. Never imported at serving time."""
