"""Layer-1 Pallas kernel: quantized EmbeddingBag with fused ABFT checksum
(paper Alg 2).

One grid step per bag: the kernel gathers `pooling` quantized rows,
accumulates `α_i · row + β_i` into the f32 output, and *fuses* the Eq-5
checksum sides — RSum (output sum) and CSum (α_i·C_T[i] + d·β_i over the
bag) — so verification costs one extra scalar pass instead of re-reading
the output.

TPU adaptation: gathers are the HBM-bound part; on real hardware the
BlockSpec keeps the index vector and per-row qparams in VMEM/SMEM while
rows stream from HBM (the paper's software-prefetch distance becomes the
double-buffer depth). interpret=True as everywhere.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _eb_kernel(table_ref, alpha_ref, beta_ref, ct_ref, idx_ref, out_ref, rsum_ref, csum_ref):
    d = out_ref.shape[-1]
    pooling = idx_ref.shape[-1]

    def body(p, carry):
        acc, csum = carry
        i = idx_ref[0, p]
        row = table_ref[i, :].astype(jnp.float32)
        a = alpha_ref[i]
        b = beta_ref[i]
        acc = acc + a * row + b
        csum = csum + a * ct_ref[i].astype(jnp.float32) + d * b
        return acc, csum

    acc, csum = jax.lax.fori_loop(
        0, pooling, body, (jnp.zeros((d,), jnp.float32), jnp.float32(0.0))
    )
    out_ref[0, :] = acc
    rsum_ref[0] = jnp.sum(acc)
    csum_ref[0] = csum


@functools.partial(jax.jit, static_argnames=())
def eb_abft(table, alpha, beta, c_t, indices):
    """Protected EmbeddingBag.

    table: (rows, d) u8; alpha/beta: (rows,) f32; c_t: (rows,) i32
    (precomputed code row sums); indices: (batch, pooling) i32.

    Returns (result (batch, d) f32, rsum (batch,) f32, csum (batch,) f32);
    a bag is flagged when |rsum - csum| exceeds the relative bound
    (decided by the caller — rust keeps the policy).
    """
    batch, pooling = indices.shape
    rows, d = table.shape
    return pl.pallas_call(
        _eb_kernel,
        grid=(batch,),
        in_specs=[
            pl.BlockSpec((rows, d), lambda b: (0, 0)),
            pl.BlockSpec((rows,), lambda b: (0,)),
            pl.BlockSpec((rows,), lambda b: (0,)),
            pl.BlockSpec((rows,), lambda b: (0,)),
            pl.BlockSpec((1, pooling), lambda b: (b, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, d), lambda b: (b, 0)),
            pl.BlockSpec((1,), lambda b: (b,)),
            pl.BlockSpec((1,), lambda b: (b,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((batch, d), jnp.float32),
            jax.ShapeDtypeStruct((batch,), jnp.float32),
            jax.ShapeDtypeStruct((batch,), jnp.float32),
        ],
        interpret=True,
    )(table, alpha, beta, c_t, indices)


def flag_bags(rsum, csum, rel_bound=1e-5):
    """Eq-5 decision (paper §V-D): relative round-off bound."""
    scale = jnp.maximum(jnp.maximum(jnp.abs(rsum), jnp.abs(csum)), 1.0)
    return jnp.abs(rsum - csum) > rel_bound * scale
