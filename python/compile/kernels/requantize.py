"""Layer-1 Pallas kernel: requantization (paper Fig 1, §IV-A3).

Combines the 32-bit protected intermediate `C_temp[m, n+1]` with the
Eq-1 rank-1 correction terms and emits the u8 output tuple — *excluding
the checksum column*, exactly the paper's modified requantization
("we just need to modify the requantization procedure to let it exclude
the last column of the intermediate 32-bit matrix").

One grid step per (m-tile); the kernel reads the C tile plus the
precomputed row/column sums (SMEM-friendly vectors on a real TPU) and
writes the u8 tile. Quantized ReLU (clamp at the zero code) is fused.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _requant_kernel(c_ref, arow_ref, bcol_ref, params_ref, o_ref):
    """params = [x_alpha, x_beta, w_alpha, w_beta, out_alpha, out_beta,
    k, relu_flag] (f32)."""
    x_alpha = params_ref[0]
    x_beta = params_ref[1]
    w_alpha = params_ref[2]
    w_beta = params_ref[3]
    out_alpha = params_ref[4]
    out_beta = params_ref[5]
    k = params_ref[6]
    relu = params_ref[7]

    payload = c_ref[:, :-1].astype(jnp.float32)  # checksum column excluded
    arow = arow_ref[...].astype(jnp.float32)[:, None]
    bcol = bcol_ref[...].astype(jnp.float32)[None, :]
    real = (
        x_alpha * w_alpha * payload
        + x_alpha * w_beta * arow
        + w_alpha * x_beta * bcol
        + k * x_beta * w_beta
    )
    y = jnp.clip(jnp.round((real - out_beta) / out_alpha), 0, 255)
    zero_code = jnp.clip(jnp.round((0.0 - out_beta) / out_alpha), 0, 255)
    y = jnp.where(relu > 0.5, jnp.maximum(y, zero_code), y)
    o_ref[...] = y.astype(jnp.uint8)


@functools.partial(jax.jit, static_argnames=("relu", "k"))
def requantize_exclude_last_col(c_temp, a_row_sums, b_col_sums, x_qp, w_qp, out_qp, k, relu=False):
    """Protected-C requantization.

    c_temp: (m, n+1) i32 (last column = ABFT checksum, dropped);
    a_row_sums: (m,) i32; b_col_sums: (n,) i32; *_qp: (alpha, beta)
    float pairs; k: inner dimension. Returns (m, n) u8.
    """
    m, n1 = c_temp.shape
    n = n1 - 1
    assert a_row_sums.shape == (m,)
    assert b_col_sums.shape == (n,)
    params = jnp.array(
        [x_qp[0], x_qp[1], w_qp[0], w_qp[1], out_qp[0], out_qp[1], float(int(k)), 1.0 if relu else 0.0],
        dtype=jnp.float32,
    )
    return pl.pallas_call(
        _requant_kernel,
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.uint8),
        interpret=True,
    )(c_temp, a_row_sums, b_col_sums, params)
