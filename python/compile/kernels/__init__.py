"""Layer-1 Pallas kernels (build-time python, interpret=True on CPU)."""

from . import abft_gemm, embeddingbag, ref, requantize  # noqa: F401
