"""Pure-jnp oracles for the Pallas kernels.

Every kernel in this package is validated against these references at
build time (pytest); the references themselves mirror the rust substrate
(`rust/src/abft/gemm.rs`, `rust/src/abft/eb.rs`) bit-for-bit on integers.
"""

import jax.numpy as jnp

MODULUS = 127  # paper §IV-A2 / §IV-C: largest odd prime in the i8 range


def encode_checksum_col(b: jnp.ndarray, modulus: int = MODULUS) -> jnp.ndarray:
    """Mod-`modulus` row-sum checksum column of a (k, n) i8 matrix.

    Matches Algorithm 1 lines 2-5 (and rust `encode_checksum_col`):
    values lie in (-modulus, modulus) and fit i8. jnp's `%` follows the
    divisor's sign (python semantics) while rust's `%` truncates; we
    emulate truncation to stay bit-identical with the rust encoder.
    """
    s = jnp.sum(b.astype(jnp.int32), axis=1)
    rem = jnp.sign(s) * (jnp.abs(s) % modulus)  # truncated remainder
    return rem.astype(jnp.int8)


def encode(b: jnp.ndarray, modulus: int = MODULUS) -> jnp.ndarray:
    """Append the checksum column: (k, n) i8 -> (k, n+1) i8 (the packed B')."""
    col = encode_checksum_col(b, modulus)
    return jnp.concatenate([b, col[:, None]], axis=1)


def qgemm(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """u8 x i8 -> i32 reference matmul."""
    return jnp.dot(
        a.astype(jnp.int32), b.astype(jnp.int32), preferred_element_type=jnp.int32
    )


def abft_qgemm_ref(a: jnp.ndarray, b_enc: jnp.ndarray) -> jnp.ndarray:
    """Protected GEMM reference: (m, k) u8 x (k, n+1) i8 -> (m, n+1) i32."""
    return qgemm(a, b_enc)


def verify_rows(c_temp: jnp.ndarray, modulus: int = MODULUS) -> jnp.ndarray:
    """Eq 3b residuals per row; 0 == clean.

    Accumulates mod-first (`Σ(c_j mod p) mod p`) so everything stays in
    i32 — a plain i32 row sum overflows for n·|entry| > 2^31 (the rust
    side uses i64 instead; both test the same congruence).
    """
    payload = c_temp[:, :-1] % modulus  # python-style mod: in [0, p)
    t = jnp.sum(payload, axis=1)
    diff = (t - c_temp[:, -1]) % modulus
    return diff.astype(jnp.int32)


def eb_ref(table, alpha, beta, indices):
    """EmbeddingBag reference over one batch.

    table: (rows, d) u8; alpha/beta: (rows,) f32;
    indices: (batch, pooling) i32 -> (batch, d) f32.
    """
    rows = table[indices]  # (batch, pooling, d)
    a = alpha[indices][..., None]
    b = beta[indices][..., None]
    return jnp.sum(a * rows.astype(jnp.float32) + b, axis=1)


def eb_checksum_ref(table):
    """C_T: integer code row sums (§V-B keeps them unscaled in i32)."""
    return jnp.sum(table.astype(jnp.int32), axis=1)


def eb_verify_ref(result, c_t, alpha, beta, indices, d, rel_bound=1e-5):
    """Eq 5 residual check per bag; True == flagged."""
    rsum = jnp.sum(result, axis=1)
    csum = jnp.sum(
        alpha[indices] * c_t[indices].astype(jnp.float32) + d * beta[indices],
        axis=1,
    )
    scale = jnp.maximum(jnp.maximum(jnp.abs(rsum), jnp.abs(csum)), 1.0)
    return jnp.abs(rsum - csum) > rel_bound * scale
