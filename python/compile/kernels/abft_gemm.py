"""Layer-1 Pallas kernel: ABFT-protected quantized GEMM (paper Alg 1).

The kernel multiplies u8 activations against the *encoded* weight panel
B' = [B | S_B] (checksum column packed contiguously, §IV-A3) so protection
rides inside a single tiled matmul: `C_temp[m, n+1] = A[m, k] · B'[k, n+1]`
in i32.

TPU adaptation (DESIGN.md §Hardware-Adaptation): the BlockSpec schedule
below is the VMEM double-buffering plan — an (bm × bk) A tile and a
(bk × bn) B' tile stream through VMEM per grid step while the MXU
accumulates the (bm × bn) C tile across the k grid axis; the checksum
column is just one extra RHS column riding in the last n-tile
((n+1)/n MXU overhead). `interpret=True` everywhere: the CPU PJRT client
cannot run Mosaic custom-calls; real-TPU numbers are estimated in
DESIGN.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

MODULUS = ref.MODULUS


def _matmul_kernel(a_ref, b_ref, o_ref):
    """One (bm, bn) output tile; accumulates across the k grid axis."""

    @pl.when(pl.program_id(2) == 0)
    def _zero():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = a_ref[...].astype(jnp.int32)
    b = b_ref[...].astype(jnp.int32)
    o_ref[...] += jnp.dot(a, b, preferred_element_type=jnp.int32)


def _pad_to(x, axis, multiple):
    size = x.shape[axis]
    pad = (-size) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def abft_qgemm(a, b_enc, bm=8, bn=128, bk=128):
    """Protected GEMM: (m, k) u8 × (k, n+1) i8 → (m, n+1) i32.

    Zero padding is checksum-transparent: padded k-rows contribute 0 to
    every dot product and padded n-columns sit to the right of the
    checksum column and are sliced off.
    """
    m, k = a.shape
    k2, n1 = b_enc.shape
    assert k == k2, f"inner dims {k} != {k2}"
    a_p = _pad_to(_pad_to(a, 0, bm), 1, bk)
    b_p = _pad_to(_pad_to(b_enc, 0, bk), 1, bn)
    mp, kp = a_p.shape
    np_ = b_p.shape[1]
    grid = (mp // bm, np_ // bn, kp // bk)
    out = pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, p: (i, p)),
            pl.BlockSpec((bk, bn), lambda i, j, p: (p, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, p: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.int32),
        interpret=True,
    )(a_p, b_p)
    return out[:m, :n1]


def _verify_kernel(c_ref, r_ref):
    """Per-row Eq 3b residual, mod-first so accumulation stays in i32
    (a raw i32 row sum overflows once n·|entry| > 2^31)."""
    c = c_ref[...]
    payload = c[:, :-1] % MODULUS  # in [0, MODULUS)
    t = jnp.sum(payload, axis=1)
    r_ref[...] = ((t - c[:, -1]) % MODULUS).astype(jnp.int32)


@jax.jit
def verify_rows(c_temp):
    """Row residuals of a protected C_temp: (m, n+1) i32 → (m,) i32."""
    m = c_temp.shape[0]
    return pl.pallas_call(
        _verify_kernel,
        out_shape=jax.ShapeDtypeStruct((m,), jnp.int32),
        interpret=True,
    )(c_temp)


@jax.jit
def err_count(c_temp):
    """Algorithm 1's errCount: number of corrupted rows."""
    return jnp.sum((verify_rows(c_temp) != 0).astype(jnp.int32))
